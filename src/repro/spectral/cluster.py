"""Spectral clustering and partitioning on top of the eigensolver.

Paper §1's motivating workloads: ``fiedler``/``fiedler_bisect`` (two-way
partition by the second eigenvector, with a conductance-minimizing sweep
cut), ``spectral_clustering`` (k-means on the k-eigenvector embedding),
``recursive_bisection`` (2^m-way partitioning), and the quality metrics
(``conductance``, ``normalized_cut``, ``cut_weight``) everything is scored
with. Solves ride the cached multigrid hierarchy via
:func:`repro.spectral.lobpcg.lobpcg`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.spectral.embed import EmbeddingResult, spectral_embedding
from repro.spectral.lobpcg import lobpcg

__all__ = ["ClusterResult", "conductance", "cut_weight", "fiedler",
           "fiedler_bisect", "kmeans", "normalized_cut",
           "recursive_bisection", "spectral_clustering", "sweep_cut"]


# ----------------------------------------------------------------------
# quality metrics (all on the directed both-ways edge list a Problem holds)
# ----------------------------------------------------------------------

def cut_weight(problem, labels) -> float:
    """Total weight of edges whose endpoints get different labels."""
    labels = np.asarray(labels)
    cross = labels[problem.rows] != labels[problem.cols]
    # each undirected edge appears in both directions: halve the sum
    return float(np.asarray(problem.vals, np.float64)[cross].sum() / 2)


def conductance(problem, mask) -> float:
    """cut(S, V\\S) / min(vol(S), vol(V\\S)) for the vertex set ``mask``.

    0 for a perfect separation, high for a cut through dense regions;
    degenerate cuts (empty side) return inf.
    """
    mask = np.asarray(mask, bool)
    vals = np.asarray(problem.vals, np.float64)
    cut = float(vals[mask[problem.rows] & ~mask[problem.cols]].sum())
    deg = np.asarray(problem.degrees(), np.float64)
    vol_s = float(deg[mask].sum())
    vol_c = float(deg.sum()) - vol_s
    denom = min(vol_s, vol_c)
    return cut / denom if denom > 0 else float("inf")


def normalized_cut(problem, labels) -> float:
    """Shi–Malik normalized cut: sum_c cut(c, rest) / vol(c)."""
    labels = np.asarray(labels)
    vals = np.asarray(problem.vals, np.float64)
    deg = np.asarray(problem.degrees(), np.float64)
    total = 0.0
    for c in np.unique(labels):
        in_c = labels == c
        cut = float(vals[in_c[problem.rows] & ~in_c[problem.cols]].sum())
        vol = float(deg[in_c].sum())
        total += cut / vol if vol > 0 else 0.0
    return total


# ----------------------------------------------------------------------
# Fiedler bisection
# ----------------------------------------------------------------------

def fiedler(problem, **lobpcg_kwargs) -> tuple[np.ndarray, float]:
    """The Fiedler pair: (second-smallest eigenvector, eigenvalue).

    One ``lobpcg`` call with k=1 (the constant vector is deflated, so the
    smallest *nontrivial* pair is exactly the Fiedler pair). Keyword
    arguments forward to :func:`repro.spectral.lobpcg.lobpcg` —
    ``backend=``, ``cache=``, ``tol=``, ...
    """
    eig = lobpcg(problem, 1, **lobpcg_kwargs)
    return np.asarray(eig.eigenvectors[:, 0], np.float64), float(
        eig.eigenvalues[0])


def sweep_cut(problem, score) -> tuple[np.ndarray, float]:
    """Best-conductance prefix cut of vertices ordered by ``score``.

    The standard rounding of a Fiedler vector (Cheeger sweep): sort
    vertices by score, evaluate the conductance of every prefix with an
    incremental cut update, return ``(mask, conductance)`` for the best.
    """
    import scipy.sparse as sp

    n = problem.n
    score = np.asarray(score, np.float64)
    order = np.argsort(score, kind="stable")
    a = sp.csr_matrix(
        (np.asarray(problem.vals, np.float64),
         (np.asarray(problem.rows), np.asarray(problem.cols))),
        shape=(n, n))
    deg = np.asarray(problem.degrees(), np.float64)
    vol_total = float(deg.sum())
    in_s = np.zeros(n, bool)
    cut = 0.0
    vol = 0.0
    best_phi, best_i = float("inf"), 0
    for i, v in enumerate(order[:-1]):
        lo, hi = a.indptr[v], a.indptr[v + 1]
        w_to_s = float(a.data[lo:hi][in_s[a.indices[lo:hi]]].sum())
        cut += deg[v] - 2.0 * w_to_s
        vol += deg[v]
        in_s[v] = True
        denom = min(vol, vol_total - vol)
        phi = cut / denom if denom > 0 else float("inf")
        if phi < best_phi:
            best_phi, best_i = phi, i
    mask = np.zeros(n, bool)
    mask[order[: best_i + 1]] = True
    return mask, best_phi


def fiedler_bisect(problem, *, sweep: bool = True, **lobpcg_kwargs
                   ) -> tuple[np.ndarray, dict]:
    """Two-way partition by the Fiedler vector.

    ``sweep=True`` (default) rounds with the conductance-minimizing sweep
    cut; ``False`` uses the plain sign cut. Returns ``(mask, info)`` with
    ``info`` holding ``fiedler_value``, ``conductance`` and ``cut_weight``.
    """
    vec, lam = fiedler(problem, **lobpcg_kwargs)
    if sweep:
        mask, phi = sweep_cut(problem, vec)
    else:
        mask = vec > 0          # mean-free, so both signs are populated
        phi = conductance(problem, mask)
    return mask, dict(fiedler_value=lam, conductance=phi,
                      cut_weight=cut_weight(problem, mask.astype(np.int8)))


# ----------------------------------------------------------------------
# k-means (hand-rolled, seeded — no sklearn in the container)
# ----------------------------------------------------------------------

def kmeans(X, k: int, *, seed: int = 0, n_init: int = 4,
           max_iters: int = 100) -> tuple[np.ndarray, np.ndarray, float]:
    """Lloyd's k-means with k-means++ seeding and ``n_init`` restarts.

    Returns ``(labels, centers, inertia)`` of the best restart. Fully
    deterministic for a fixed seed.
    """
    X = np.asarray(X, np.float64)
    n = X.shape[0]
    if not 1 <= k <= n:
        raise ValueError(f"k must be in [1, {n}], got {k}")
    rng = np.random.default_rng(seed)
    best = None
    for _ in range(max(1, n_init)):
        centers = np.empty((k, X.shape[1]))
        centers[0] = X[rng.integers(n)]
        d2 = ((X - centers[0]) ** 2).sum(axis=1)
        for j in range(1, k):           # k-means++: D^2 sampling
            p = d2 / d2.sum() if d2.sum() > 0 else np.full(n, 1.0 / n)
            centers[j] = X[rng.choice(n, p=p)]
            d2 = np.minimum(d2, ((X - centers[j]) ** 2).sum(axis=1))
        labels = np.zeros(n, np.int64)
        for _ in range(max_iters):
            dist = ((X[:, None, :] - centers[None, :, :]) ** 2).sum(axis=2)
            new_labels = dist.argmin(axis=1)
            if (new_labels == labels).all() and _ > 0:
                break
            labels = new_labels
            for j in range(k):
                members = X[labels == j]
                if len(members):
                    centers[j] = members.mean(axis=0)
                else:                   # re-seed an empty cluster
                    centers[j] = X[rng.integers(n)]
        inertia = float(
            ((X - centers[labels]) ** 2).sum())
        if best is None or inertia < best[2]:
            best = (labels.copy(), centers.copy(), inertia)
    return best


# ----------------------------------------------------------------------
# spectral clustering / recursive partitioning
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class ClusterResult:
    """A vertex partition plus its quality scores.

    ``labels`` is (n,) int64 in ``[0, n_clusters)``; ``conductances`` is
    the per-cluster conductance; ``embedding`` is the spectral embedding
    the labels came from (``None`` for recursive bisection).
    """

    labels: np.ndarray
    n_clusters: int
    ncut: float
    conductances: np.ndarray
    cut_weight: float
    embedding: EmbeddingResult | None = None


def _scored(problem, labels, n_clusters, embedding=None) -> ClusterResult:
    labels = np.asarray(labels, np.int64)
    phis = np.array([conductance(problem, labels == c)
                     for c in range(n_clusters)])
    return ClusterResult(labels=labels, n_clusters=n_clusters,
                         ncut=normalized_cut(problem, labels),
                         conductances=phis,
                         cut_weight=cut_weight(problem, labels),
                         embedding=embedding)


def spectral_clustering(problem, k: int, *, embed_k: int | None = None,
                        row_normalize: bool = False, kmeans_seed: int = 0,
                        n_init: int = 4, **lobpcg_kwargs) -> ClusterResult:
    """k-way spectral clustering: k-means on the spectral embedding.

    ``embed_k`` defaults to ``max(k - 1, 1)`` nontrivial eigenvectors (the
    constant one carries no cluster information). Remaining keyword
    arguments go to :func:`lobpcg` via :func:`spectral_embedding`.
    """
    if k < 2:
        raise ValueError(f"need k >= 2 clusters, got {k}")
    embed_k = max(k - 1, 1) if embed_k is None else int(embed_k)
    emb = spectral_embedding(problem, embed_k, row_normalize=row_normalize,
                             **lobpcg_kwargs)
    labels, _, _ = kmeans(emb.coords, k, seed=kmeans_seed, n_init=n_init)
    return _scored(problem, labels, k, embedding=emb)


def _subproblem(problem, idx):
    """Induced subgraph on ``idx`` as a new Problem (validated edges)."""
    from repro.api import Problem

    idx = np.asarray(idx)
    pos = np.full(problem.n, -1, np.int64)
    pos[idx] = np.arange(len(idx))
    keep = (pos[problem.rows] >= 0) & (pos[problem.cols] >= 0)
    return Problem.from_edges(len(idx), pos[problem.rows[keep]],
                              pos[problem.cols[keep]], problem.vals[keep])


def _component_split(sub) -> np.ndarray:
    """Bisect a disconnected graph along components, balancing volume."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components

    a = sp.coo_matrix((np.ones(len(sub.rows)), (sub.rows, sub.cols)),
                      shape=(sub.n, sub.n))
    _, comp = connected_components(a, directed=False)
    deg = np.asarray(sub.degrees(), np.float64) + 1e-12
    vols = np.bincount(comp, weights=deg)
    order = np.argsort(vols)[::-1]
    side_vol = np.zeros(2)
    side_of = np.zeros(len(vols), np.int8)
    for c in order:                     # greedy balance
        s = int(side_vol[1] < side_vol[0])
        side_of[c] = s
        side_vol[s] += vols[c]
    return side_of[comp] == 1


def recursive_bisection(problem, n_parts: int, *, precond_min_n: int = 256,
                        min_part: int = 1, **lobpcg_kwargs) -> ClusterResult:
    """Partition into ``n_parts`` by recursive Fiedler bisection.

    Repeatedly sweep-cuts the largest-volume part's induced subgraph.
    Disconnected subgraphs split along their components (no solve
    needed); subgraphs smaller than ``precond_min_n`` solve
    unpreconditioned (a multigrid setup wouldn't amortize). Keyword
    arguments forward to :func:`fiedler_bisect`'s eigensolve.
    """
    if n_parts < 2:
        raise ValueError(f"need n_parts >= 2, got {n_parts}")
    deg = np.asarray(problem.degrees(), np.float64)
    parts = [np.arange(problem.n)]
    while len(parts) < n_parts:
        splittable = [i for i, p in enumerate(parts)
                      if len(p) >= max(2, 2 * min_part)]
        if not splittable:
            break
        i = max(splittable, key=lambda j: deg[parts[j]].sum())
        part = parts.pop(i)
        sub = _subproblem(problem, part)
        from repro.graphs.generators import largest_component_sizes

        if len(largest_component_sizes(sub.n, sub.rows, sub.cols)) > 1:
            mask = _component_split(sub)
        elif sub.n < 4:
            mask = np.zeros(sub.n, bool)
            mask[: sub.n // 2] = True
        else:
            kw = dict(lobpcg_kwargs)
            if sub.n < precond_min_n:
                kw.setdefault("precondition", False)
                kw.setdefault("max_iters", 500)
            mask, _ = fiedler_bisect(sub, **kw)
        parts.append(part[mask])
        parts.append(part[~mask])
    labels = np.zeros(problem.n, np.int64)
    for c, p in enumerate(parts):
        labels[p] = c
    return _scored(problem, labels, len(parts))
