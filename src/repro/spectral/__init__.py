"""``repro.spectral`` — spectral applications of the Laplacian solver.

The paper's §1 motivation made executable: graph drawing (embeddings),
spectral clustering/partitioning, effective resistance, and Laplacian
positional encodings, all riding one cached multigrid hierarchy through
the ``repro.api`` facade::

    from repro.api import Problem
    from repro.spectral import lobpcg, spectral_clustering, fiedler

    p = Problem.from_edges(n, rows, cols, vals)
    eig = lobpcg(p, k=8)                      # k smallest nontrivial pairs
    labels = spectral_clustering(p, k=4).labels
    vec, lam2 = fiedler(p)                    # Fiedler bisection input

Every eigensolver iteration's preconditioner application is a blocked
``solve_block`` against the cached hierarchy — the many-heterogeneous-RHS
traffic shape the serving layer (PR 6) was built for.
"""

from repro.spectral.cluster import (ClusterResult, conductance, cut_weight,
                                    fiedler, fiedler_bisect, kmeans,
                                    normalized_cut, recursive_bisection,
                                    spectral_clustering, sweep_cut)
from repro.spectral.embed import (EmbeddingResult, incremental_embedding,
                                  spectral_embedding)
from repro.spectral.lobpcg import EigResult, lobpcg, refine_eigenpairs
from repro.spectral.pe import (canonicalize_signs, graph_batch_with_pe,
                               laplacian_pe)
from repro.spectral.resistance import (ResistanceSketch, effective_resistance,
                                       exact_effective_resistance)

__all__ = [
    "ClusterResult",
    "EigResult",
    "EmbeddingResult",
    "ResistanceSketch",
    "canonicalize_signs",
    "conductance",
    "cut_weight",
    "effective_resistance",
    "exact_effective_resistance",
    "fiedler",
    "fiedler_bisect",
    "graph_batch_with_pe",
    "incremental_embedding",
    "kmeans",
    "laplacian_pe",
    "lobpcg",
    "normalized_cut",
    "recursive_bisection",
    "refine_eigenpairs",
    "spectral_clustering",
    "spectral_embedding",
    "sweep_cut",
]
