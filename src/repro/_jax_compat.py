"""Compatibility shims for the range of JAX versions the repo supports.

The distributed tests and examples build meshes with

    jax.make_mesh(shape, names, axis_types=(jax.sharding.AxisType.Auto,) * k)

``AxisType`` and the ``axis_types=`` keyword only exist in newer JAX
releases; on older ones (e.g. 0.4.x) every mesh axis already behaves like
``Auto``, so the spelling can be accepted and ignored without changing
semantics. ``install()`` patches both in when missing and is a no-op on
JAX versions that already provide them. It is called once from
``repro/__init__`` so any ``import repro.*`` makes the canonical spelling
work.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.sharding as shd


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def install() -> None:
    if not hasattr(shd, "AxisType"):
        shd.AxisType = _AxisType

    if getattr(jax.make_mesh, "_repro_axis_types_shim", False):
        return
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover - exotic builds
        return
    if "axis_types" in params:
        return

    orig = jax.make_mesh

    @functools.wraps(orig)
    def make_mesh(*args, axis_types=None, **kwargs):
        # Old JAX: all axes are implicitly Auto; drop the annotation.
        return orig(*args, **kwargs)

    make_mesh._repro_axis_types_shim = True
    jax.make_mesh = make_mesh
