from repro.graphs.generators import (
    barabasi_albert,
    erdos_renyi,
    grid_2d,
    delaunay,
    watts_strogatz,
    rmat,
    ensure_connected,
    to_laplacian_coo,
)
from repro.graphs.datasets import paper_graph, PAPER_GRAPHS

__all__ = [
    "barabasi_albert",
    "erdos_renyi",
    "grid_2d",
    "delaunay",
    "watts_strogatz",
    "rmat",
    "ensure_connected",
    "to_laplacian_coo",
    "paper_graph",
    "PAPER_GRAPHS",
]
