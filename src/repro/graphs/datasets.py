"""Named synthetic stand-ins for the paper's evaluation graphs (Fig 3-6).

Each entry matches the *class* and rough scale (scaled to CPU budgets) of the
original SuiteSparse / SNAP graph. Sizes are configurable via ``scale`` so
benchmarks can run quickly in CI and larger in the full harness.
"""

from __future__ import annotations

from repro.graphs import generators as G

# name -> (generator kind, default kwargs, description)
PAPER_GRAPHS = {
    # Internet AS topology snapshots: power-law, ~22k nodes.
    "as-22july06": ("ba", dict(n=22963, m=2), "AS internet topology (power-law)"),
    "as-caida": ("ba", dict(n=26475, m=2), "CAIDA AS graph (power-law)"),
    # Collaboration network: power-law with higher density.
    "ca-AstroPh": ("ba", dict(n=18772, m=11), "astro-ph collaboration"),
    # Census-block planar graph.
    "de2010": ("grid", dict(nx=180, ny=180), "Delaware census blocks (planar)"),
    # Delaunay triangulation of 2^13 points (exact construction, not stand-in).
    "delaunay_n13": ("delaunay", dict(n=8192), "delaunay_n13 (exact class)"),
    # Web crawl: power-law, directed origins; symmetrised.
    "web-NotreDame": ("rmat", dict(scale=15, edge_factor=5), "web crawl (rmat)"),
    "coAuthorsCiteseer": ("ba", dict(n=227320 // 8, m=4), "coauthor network"),
    # Strong-scaling graph: dense power-law (hollywood-2009 is 1.1M/113M; the
    # stand-in keeps the density ratio at reduced n).
    "hollywood-2009": ("ba", dict(n=40000, m=50), "actor collaboration (dense power-law)"),
}


def paper_graph(name: str, scale: float = 1.0, seed: int = 0,
                weighted: bool = False):
    """Return (n, rows, cols, vals) for a named stand-in graph."""
    kind, kwargs, _ = PAPER_GRAPHS[name]
    kwargs = dict(kwargs)
    if kind == "ba":
        kwargs["n"] = max(int(kwargs["n"] * scale), 16)
        g = G.barabasi_albert(seed=seed, weighted=weighted, **kwargs)
    elif kind == "grid":
        kwargs["nx"] = max(int(kwargs["nx"] * scale**0.5), 4)
        kwargs["ny"] = max(int(kwargs["ny"] * scale**0.5), 4)
        g = G.grid_2d(seed=seed, weighted=weighted, **kwargs)
    elif kind == "delaunay":
        kwargs["n"] = max(int(kwargs["n"] * scale), 16)
        g = G.delaunay(seed=seed, weighted=weighted, **kwargs)
    elif kind == "rmat":
        if scale < 1.0:
            kwargs["scale"] = max(kwargs["scale"] - max(int(round(-_log2(scale))), 0), 6)
        g = G.rmat(seed=seed, weighted=weighted, **kwargs)
    else:  # pragma: no cover
        raise ValueError(kind)
    return G.ensure_connected(*g, seed=seed)


def _log2(x: float) -> float:
    import math

    return math.log2(x)
