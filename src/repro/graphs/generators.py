"""Seeded synthetic graph generators (host-side numpy).

The paper's test set (SuiteSparse / SNAP graphs) isn't redistributable
offline, so these generate structurally-matched stand-ins:

* ``barabasi_albert`` — power-law degree social/AS-style networks (the
  paper's main target class: hubs + heavy tail),
* ``rmat`` — Kronecker power-law graphs (Graph500-style),
* ``delaunay`` — the `delauney_nXX` family (planar, bounded degree),
* ``grid_2d`` — census/mesh-like planar graphs (de2010 stand-in),
* ``watts_strogatz`` — small-world.

All generators return ``(n, rows, cols, vals)`` with BOTH edge directions
present, no self loops, positive float32 weights, numpy arrays. Use
``ensure_connected`` to add a random spanning chain (the paper assumes
connected graphs; the Laplacian nullspace is then exactly the constants).
"""

from __future__ import annotations

import numpy as np


def _dedup_sym(n, u, v, w=None, rng=None):
    """Symmetrise + dedup an undirected edge list given as (u, v) pairs."""
    keep = u != v
    u, v = u[keep], v[keep]
    if w is not None:
        w = w[keep]
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    key = lo.astype(np.int64) * n + hi
    _, idx = np.unique(key, return_index=True)
    lo, hi = lo[idx], hi[idx]
    if w is None:
        w = np.ones(len(lo), np.float32) if rng is None else rng.uniform(
            0.5, 1.5, len(lo)).astype(np.float32)
    else:
        w = w[idx].astype(np.float32)
    rows = np.concatenate([lo, hi]).astype(np.int32)
    cols = np.concatenate([hi, lo]).astype(np.int32)
    vals = np.concatenate([w, w])
    return n, rows, cols, vals


def barabasi_albert(n: int, m: int = 4, seed: int = 0, weighted: bool = False):
    """Preferential attachment; degree tail ~ k^-3. O(n·m) with a
    preallocated repeated-endpoint array (sampling an index into it IS
    degree-proportional sampling; duplicates within a step are dropped, the
    standard BA approximation)."""
    rng = np.random.default_rng(seed)
    repeated = np.empty(2 * n * m + 2 * m, np.int64)
    repeated[:m] = np.arange(m)
    size = m
    src = np.empty(n * m, np.int64)
    dst = np.empty(n * m, np.int64)
    e = 0
    for v in range(m, n):
        chosen = np.unique(repeated[rng.integers(0, size, m)])
        k = len(chosen)
        src[e: e + k] = v
        dst[e: e + k] = chosen
        e += k
        repeated[size: size + k] = chosen
        repeated[size + k: size + 2 * k] = v
        size += 2 * k
    return _dedup_sym(n, src[:e], dst[:e], rng=rng if weighted else None)


def erdos_renyi(n: int, avg_degree: float = 8.0, seed: int = 0,
                weighted: bool = False):
    rng = np.random.default_rng(seed)
    n_edges = int(n * avg_degree / 2)
    u = rng.integers(0, n, n_edges)
    v = rng.integers(0, n, n_edges)
    return _dedup_sym(n, u, v, rng=rng if weighted else None)


def rmat(scale: int, edge_factor: int = 8, seed: int = 0,
         a=0.57, b=0.19, c=0.19, weighted: bool = False):
    """R-MAT/Kronecker generator (Graph500 parameters by default)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    n_edges = n * edge_factor
    u = np.zeros(n_edges, np.int64)
    v = np.zeros(n_edges, np.int64)
    for _ in range(scale):
        r = rng.random(n_edges)
        right = r >= a + b  # falls in c or d quadrant (row bit set)
        bottom = ((r >= a) & (r < a + b)) | (r >= a + b + c)  # col bit set
        u = (u << 1) | right.astype(np.int64)
        v = (v << 1) | bottom.astype(np.int64)
    return _dedup_sym(n, u, v, rng=rng if weighted else None)


def grid_2d(nx: int, ny: int, weighted: bool = False, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = np.arange(nx * ny).reshape(nx, ny)
    right_u = idx[:, :-1].ravel()
    right_v = idx[:, 1:].ravel()
    down_u = idx[:-1, :].ravel()
    down_v = idx[1:, :].ravel()
    u = np.concatenate([right_u, down_u])
    v = np.concatenate([right_v, down_v])
    return _dedup_sym(nx * ny, u, v, rng=rng if weighted else None)


def delaunay(n: int, seed: int = 0, weighted: bool = False):
    """Delaunay triangulation of n uniform points (scipy.spatial)."""
    from scipy.spatial import Delaunay as _Del

    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    tri = _Del(pts)
    s = tri.simplices
    u = np.concatenate([s[:, 0], s[:, 1], s[:, 2]]).astype(np.int64)
    v = np.concatenate([s[:, 1], s[:, 2], s[:, 0]]).astype(np.int64)
    return _dedup_sym(n, u, v, rng=rng if weighted else None)


def star(n: int, weighted: bool = False, seed: int = 0):
    """Hub-and-spokes star graph: vertex 0 adjacent to all others.

    The unweighted star's Laplacian spectrum is {0, 1 (multiplicity n-2),
    n} — the spectral test suite's multiplicity stress case.
    """
    rng = np.random.default_rng(seed)
    u = np.zeros(n - 1, np.int64)
    v = np.arange(1, n, dtype=np.int64)
    return _dedup_sym(n, u, v, rng=rng if weighted else None)


def watts_strogatz(n: int, k: int = 6, p: float = 0.1, seed: int = 0,
                   weighted: bool = False):
    rng = np.random.default_rng(seed)
    base = np.arange(n, dtype=np.int64)
    us, vs = [], []
    for d in range(1, k // 2 + 1):
        tgt = (base + d) % n
        rewire = rng.random(n) < p
        tgt = np.where(rewire, rng.integers(0, n, n), tgt)
        us.append(base)
        vs.append(tgt)
    return _dedup_sym(n, np.concatenate(us), np.concatenate(vs),
                      rng=rng if weighted else None)


def ensure_connected(n, rows, cols, vals, seed: int = 0):
    """Bridge connected components so the graph is connected.

    The paper assumes connected inputs. If the generator output is already
    connected this is a no-op (important: adding shortcut edges would turn
    mesh-like graphs into small-world expanders and collapse their condition
    number, invalidating the Fig 3 comparisons). Otherwise one random vertex
    of each component is chained to the next component.
    """
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components

    a = sp.coo_matrix((np.ones(len(rows)), (rows, cols)), shape=(n, n))
    ncomp, labels = connected_components(a, directed=False)
    if ncomp <= 1:
        return n, rows.astype(np.int32), cols.astype(np.int32), vals.astype(np.float32)
    rng = np.random.default_rng(seed + 12345)
    reps = np.empty(ncomp, np.int64)
    for comp in range(ncomp):
        members = np.flatnonzero(labels == comp)
        reps[comp] = rng.choice(members)
    u, v = reps[:-1], reps[1:]
    w = np.full(ncomp - 1, float(np.median(vals)) if len(vals) else 1.0,
                np.float32)
    out_r = np.concatenate([rows.astype(np.int64), u, v]).astype(np.int32)
    out_c = np.concatenate([cols.astype(np.int64), v, u]).astype(np.int32)
    out_w = np.concatenate([vals.astype(np.float32), w, w])
    return n, out_r, out_c, out_w


def random_relabel(n, rows, cols, seed: int):
    """The paper's §2.2 random vertex relabeling, shared by every solver.

    A pure relabeling: ``new = perm[old]``. Returns ``(rows, cols, perm,
    inv_perm)``; callers map RHS/solutions with ``b[inv_perm]`` /
    ``x[perm]`` so the ordering is transparent to users.
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)            # old id -> new id
    inv_perm = np.argsort(perm)
    return perm[rows], perm[cols], perm, inv_perm


def to_laplacian_coo(n, rows, cols, vals, capacity=None):
    """Adjacency edge list -> padded COO of the adjacency (off-diag part).

    The solver represents every level by its adjacency + derived degrees
    (DESIGN.md §4); the Laplacian is L = diag(deg) − A.
    """
    from repro.sparse.coo import coo_from_arrays

    return coo_from_arrays(rows, cols, vals, n, n, capacity=capacity)


def largest_component_sizes(n, rows, cols) -> np.ndarray:
    """Connected component sizes (scipy) — test/validation helper."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components

    a = sp.coo_matrix((np.ones(len(rows)), (rows, cols)), shape=(n, n))
    ncomp, labels = connected_components(a, directed=False)
    return np.bincount(labels, minlength=ncomp)
