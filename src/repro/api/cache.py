"""``HierarchyCache``: content-addressed reuse of multigrid setups.

The paper's setup phase dominates a single solve, and PRs 4-5 made its
compiled programs reusable across same-bucket graphs. This layer makes the
*hierarchies themselves* reusable across requests: a setup is an immutable
artifact addressed by ``(Problem.fingerprint(), bucket signature, options,
backend, mesh)``, and a second ``setup()``/``solve()`` on an equal Problem
is a dictionary lookup — zero super-step compiles, zero host syncs (the
facade threads every call through a default cache; see
``repro.api.facade.setup``).

The cache stores backend *handles* (the object ``solve_block`` runs
against), so a hit skips hierarchy construction on any backend, and the
LRU bound keeps device memory proportional to the working set, not the
request history.
"""

from __future__ import annotations

from collections import OrderedDict


def _mesh_signature(mesh) -> tuple | None:
    if mesh is None:
        return None
    return (tuple(mesh.shape.items()),
            tuple(str(d) for d in mesh.devices.flat))


class HierarchyCache:
    """LRU cache of backend handles keyed on problem content + options.

    ``capacity`` bounds the number of retained hierarchies (least
    recently used evicted first). ``stats()`` surfaces hit/miss/eviction
    counters so serving deployments can watch their working set.

    Thread-unaware by design: the serving layer (``repro.service``) is a
    deterministic synchronous driver, and the facade's default cache is
    only touched from the calling thread.
    """

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._invalidations = 0

    # ------------------------------------------------------------------
    @staticmethod
    def key(problem, options, backend: str, mesh=None) -> tuple:
        """The cache key: ``(fingerprint, bucket-signature, options,
        backend, mesh-signature)``. ``options`` is a frozen dataclass and
        hashes by value; the bucket signature is technically implied by
        (fingerprint, options) but kept explicit so keys group visibly by
        compiled-program reuse class."""
        return (problem.fingerprint(),
                problem.bucket_signature(options.setup_bucket_floor),
                options, backend, _mesh_signature(mesh))

    def get(self, key):
        """The cached handle for ``key``, or None (counts a hit/miss)."""
        entry = self._entries.get(key)
        if entry is None:
            self._misses += 1
            return None
        self._entries.move_to_end(key)
        self._hits += 1
        return entry

    def peek(self, key):
        """The cached handle for ``key`` or None, WITHOUT touching the
        hit/miss counters or the LRU order (for callers that already
        counted the lookup — e.g. the service's admission probe)."""
        return self._entries.get(key)

    def put(self, key, handle) -> None:
        """Insert (or refresh) ``key``; evicts LRU entries past capacity."""
        if key in self._entries:
            self._entries.move_to_end(key)
        self._entries[key] = handle
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self._evictions += 1

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    def clear(self) -> None:
        """Drop every entry (counters keep accumulating)."""
        self._entries.clear()

    def invalidate(self, fingerprint: str) -> int:
        """Evict every entry for a Problem fingerprint; returns the count.

        The poisoned-hierarchy path: when a cached hierarchy produces a
        Krylov breakdown, the facade's degradation ladder evicts all of
        that problem's entries (every options/backend/mesh variant — the
        setup artifact itself is suspect) before rebuilding, so the bad
        artifact cannot keep serving future requests.
        """
        doomed = [k for k in self._entries if k[0] == fingerprint]
        for k in doomed:
            del self._entries[k]
        self._invalidations += len(doomed)
        return len(doomed)

    def stats(self) -> dict:
        """Size/capacity plus hit/miss/eviction/invalidation counters
        and hit rate."""
        total = self._hits + self._misses
        return dict(size=len(self._entries), capacity=self.capacity,
                    hits=self._hits, misses=self._misses,
                    evictions=self._evictions,
                    invalidations=self._invalidations,
                    hit_rate=(self._hits / total) if total else 0.0)
