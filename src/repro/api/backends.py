"""The built-in backends behind the ``repro.api`` facade.

Each backend adapts one of the repo's solver implementations to the common
handle protocol the facade consumes:

    handle = setup_fn(problem, options, mesh)
    X, norms, iters = handle.solve_block(B, tol, max_iters)   # B: (n, k)
    handle.work_per_iteration                                 # WDA units
    handle.stats()                                            # hierarchy dict

``solve_block`` always takes and returns 2-D blocks; the facade does the
(n,) <-> (n, 1) plumbing. ``norms`` is the (T+1, k) lockstep residual
history, ``iters`` the per-column iteration counts.

PR 8 extends the protocol with a fourth element: per-column Krylov status
codes (``repro.core.krylov``). The facade still accepts the legacy
3-tuple from third-party handles (statuses are then None and the
degradation ladder never triggers for them).
"""

from __future__ import annotations

import numpy as np

from repro.api.registry import register_backend


def default_mesh():
    """A √P×√P-ish ("data", "model") mesh over all visible devices.

    Used when the dist backend is selected (explicitly or by ``"auto"``)
    without a mesh: the device count is factored as pr × pc with pr the
    largest divisor ≤ √P, matching the paper's 2D processor grid.
    """
    import jax

    ndev = len(jax.devices())
    pr = max(d for d in range(1, int(ndev ** 0.5) + 1) if ndev % d == 0)
    return jax.make_mesh((pr, ndev // pr), ("data", "model"))


class _EagerHandle:
    """Handle over a ``LaplacianSolver`` (the ``single`` and ``serial_ref``
    backends share the solve phase; only hierarchy construction differs)."""

    def __init__(self, solver, options):
        self._solver = solver
        self._options = options
        self.work_per_iteration = solver.iteration_work(
            precondition=options.precondition)
        # PR 10: the ABFT checksum closure is built ONCE per handle from
        # the clean setup-time operator (deg, and — paranoid — a clean
        # u = Lw witness product), so later operator corruption cannot
        # poison the reference the checks compare against.
        vcfg = options.verify_config()
        self._check = None
        if vcfg is not None:
            from repro.core.verify import make_check

            self._check = make_check(solver._fine.deg, vcfg,
                                     matvec=solver.matvec)

    def solve_block(self, B, tol: float, max_iters: int, x0=None,
                    guard=None):
        # ``guard`` overrides the options-derived policy for this call
        # (the triage layer passes a tightened GuardConfig); None keeps
        # the options default.
        g = self._options.guard_config() if guard is None else guard
        X, info = self._solver.solve_block(
            B, tol=tol, maxiter=max_iters,
            precondition=self._options.precondition,
            exact_columns=self._options.exact_columns, x0=x0,
            guard=g or False, check=self._check)
        return (np.asarray(X), info.residual_norms,
                np.asarray(info.iters, np.int64), info.status)

    def stats(self) -> dict:
        return self._solver.stats()


class _DistHandle:
    """Handle over a ``DistLaplacianSolver`` on a device mesh."""

    def __init__(self, solver, options):
        self._solver = solver
        self._options = options
        self.work_per_iteration = solver.work_per_iteration
        # PR 10: checksum closure over the PADDED iteration space (the
        # scanned PCG's P/Ap blocks are [n_pad, k]); padded rows carry
        # deg=0 and the padded operator is symmetric, so both the
        # column-sum identity and the Rademacher witness hold unchanged.
        vcfg = options.verify_config()
        self._check = None
        if vcfg is not None:
            import jax.numpy as jnp

            from repro.core.verify import make_check
            from repro.dist.solver import DistGraphLevel

            fine = solver.arrays.fine
            if isinstance(fine, DistGraphLevel):
                deg = jnp.pad(fine.deg, (0, solver.n_pad - solver.n))
                mv = fine.matvec_padded
            else:                       # replicated fallback: n_pad == n
                deg = fine.deg
                mv = fine.laplacian_matvec
            self._check = make_check(deg, vcfg, matvec=mv)

    def solve_block(self, B, tol: float, max_iters: int, x0=None,
                    guard=None):
        if x0 is not None:
            raise NotImplementedError(
                "the dist backend's scanned solve does not accept per-column "
                "initial guesses yet; use backend='single' or 'serial_ref' "
                "for x0 warm starts")
        g = self._options.guard_config() if guard is None else (guard or None)
        check = self._check
        if check is not None and g is None:
            # the SDC verdict needs the in-scan code lane to land in
            from repro.core.krylov import GuardConfig

            g = GuardConfig()
        if g is not None and (self._options.guard_mode == "in_scan"
                              or check is not None):
            # PR 9: the guards run INSIDE the scanned program as status
            # lanes — statuses are live device truth (an indefinite p·Ap
            # freezes the column before the poisoned update, which a
            # norms-only postmortem can never see). Clean paths are
            # bitwise-unchanged (BENCH_robust.json dist bitwise check).
            from repro.core.krylov import scan_status_from_codes

            X, norms, iters, codes = self._solver.solve_block(
                B, n_iters=max_iters, tol=tol, guard=g, check=check)
            norms = np.asarray(norms)
            statuses = scan_status_from_codes(codes, norms, tol, norms[0])
        elif g is not None:
            # guard_mode="postmortem": the pre-PR 9 unguarded program plus
            # the (deprecated) host-side norms reconstruction — callers who
            # opted into postmortem mode see its DeprecationWarning.
            from repro.core.krylov import scan_norms_status

            X, norms, iters = self._solver.solve_block(B, n_iters=max_iters,
                                                       tol=tol)
            norms = np.asarray(norms)
            statuses = scan_norms_status(norms, tol, norms[0])
        else:
            # guards off: converged/max_iters/non-finite derived from the
            # fetched norms is the *intended* semantics here, not a
            # postmortem cross-check — use the silent internal helper.
            from repro.core.krylov import _norms_status

            X, norms, iters = self._solver.solve_block(B, n_iters=max_iters,
                                                       tol=tol)
            norms = np.asarray(norms)
            statuses = _norms_status(norms, tol, norms[0])
        return (np.asarray(X), norms, np.asarray(iters, np.int64), statuses)

    def stats(self) -> dict:
        import jax

        from repro.core.hierarchy import hierarchy_stats

        s = self._solver
        levels = [dict(kind=m.kind, n=m.n, nnz=m.nnz,
                       fill_fraction=m.fill_fraction, distributed=True,
                       ell_width=m.ell_width, ell_spill=m.ell_spill)
                  for m in s.level_meta]
        if s.coarse_h.transfers:
            tail = hierarchy_stats(s.coarse_h)
        else:
            # fully distributed hierarchy: the replicated tail is just the
            # dense coarsest solve — emit its row like hierarchy_stats does
            row = dict(kind="coarse",
                       n=int(s.coarse_h.coarse_inv.shape[0]),
                       nnz=None, capacity=None)
            if s.arrays.transfers:
                c = s.arrays.transfers[-1].coarse
                row.update(nnz=int(jax.device_get(c.adj.nnz)),
                           capacity=c.adj.capacity)
            tail = dict(levels=[row], n_levels=1)
        for lvl in tail["levels"]:
            lvl["distributed"] = False
        return dict(levels=levels + tail["levels"],
                    n_levels=len(levels) + tail["n_levels"],
                    mesh_shape=dict(s.mesh.shape))


def _setup_single(problem, options, mesh=None):
    from repro.core.solver import LaplacianSolver

    solver = LaplacianSolver.setup(
        problem.n, problem.rows, problem.cols,
        problem.vals.astype(np.float32),
        setup_config=options.setup_config(),
        cycle_config=options.cycle_config(),
        random_ordering=options.random_ordering)
    return _EagerHandle(solver, options)


def _setup_serial_ref(problem, options, mesh=None):
    from repro.core.serial_ref import serial_lamg_solver

    solver = serial_lamg_solver(
        problem.n, problem.rows, problem.cols,
        problem.vals.astype(np.float32),
        setup_config=options.setup_config(),
        cycle_config=options.cycle_config(),
        random_ordering=options.random_ordering)
    return _EagerHandle(solver, options)


def _setup_dist(problem, options, mesh=None):
    from repro.dist.solver import DistLaplacianSolver

    if not options.precondition:
        raise ValueError(
            "the dist backend always preconditions with the multigrid "
            "cycle; use backend='single' for the plain-CG ablation")
    if mesh is None:
        mesh = default_mesh()
    solver = DistLaplacianSolver.setup(
        problem.n, problem.rows, problem.cols,
        problem.vals.astype(np.float32), mesh,
        setup_config=options.setup_config(),
        cycle_config=options.cycle_config(),
        dist_nnz_threshold=options.dist_nnz_threshold,
        max_dist_levels=options.max_dist_levels,
        random_ordering=options.random_ordering)
    return _DistHandle(solver, options)


register_backend("single", _setup_single)
register_backend("serial_ref", _setup_serial_ref)
register_backend("dist", _setup_dist)
