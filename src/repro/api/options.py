"""``SolverOptions``: one knob surface for every backend.

Merges the core layer's ``SetupConfig`` (hierarchy construction),
``CycleConfig``/``SmootherConfig`` (preconditioner) and the Krylov stopping
controls into a single flat dataclass. Every backend honors ``tol`` AND
``max_iters``: the eager backends stop at whichever comes first; the
distributed backend runs a fixed-shape scan of ``max_iters`` steps in which
converged columns freeze at ``tol`` (same semantics, jit-compatible shapes).
"""

from __future__ import annotations

import dataclasses

from repro.core.aggregation import AggregationConfig
from repro.core.cycles import CycleConfig
from repro.core.hierarchy import SetupConfig
from repro.core.smoothers import SmootherConfig


@dataclasses.dataclass(frozen=True)
class SolverOptions:
    """All solver knobs, backend-agnostic. Defaults are the paper's choices.

    Stopping (honored by every backend):

    * ``tol`` — relative residual stopping tolerance (``||r|| <= tol·||r0||``),
    * ``max_iters`` — PCG iteration cap.

    Setup (hierarchy construction):

    * ``coarsest_size``, ``max_levels``, ``elim_max_degree``,
      ``strength_metric`` (``"algebraic_distance"`` | ``"affinity"``),
      ``random_ordering`` (paper §2.2 load-balancing relabeling), ``seed``.
    * ``setup_mode`` — ``"superstep"`` (default): setup runs as jitted
      super-steps compiled once per capacity bucket and reused across
      levels and graphs (``repro.core.setup_step``); ``"eager"``: the
      host-driven reference loop. Both produce equivalent hierarchies.
      Honored by every backend: on ``dist`` the super-steps run their
      Alg 1/Alg 2 semiring reductions sharded over the 2D edge partition
      of the mesh (``repro.dist.setup``) with one batched scalar fetch
      per level-advance decision.
    * ``setup_bucket_floor`` — power-of-two floor on the super-step
      padding buckets (0 = exact power-of-two buckets).
    * ``elim_sizing`` — ``"conservative"`` (default): the super-step
      elimination pass fuses Alg 1 selection and the Schur build into one
      program by sizing F-slot arrays at the vertex bucket
      (count-independent — one decision fetch per elim level);
      ``"exact"`` keeps the two-fetch split with F-slots at
      ``bucket(n_elim)``. Identical hierarchies either way.
    * ``setup_ell_sweeps`` — attach a fixed-width ELL twin before the
      setup-time strength sweeps so setup's dominant SpMV runs the fused
      kernel path too. Opt-in: changes the float summation order, so
      setup numerics then depend on ``matvec_backend``. No effect with
      ``matvec_backend="coo"``.

    Solve-phase SpMV execution format:

    * ``matvec_backend`` — ``"coo"`` (gather + segment-sum),
      ``"ell"`` (hybrid ELL+COO through the Pallas kernels on every
      level; the fused-Jacobi sweep replaces SpMV + elementwise passes),
      or ``"auto"`` (per-level layout selection: a level gets the ELL
      twin only when its degree distribution makes the fixed-width
      layout pay — see ``repro.sparse.matvec``). The distributed backend
      applies the same split to each device's local 2D edge block.

    Cycle / smoother:

    * ``cycle`` (``"V"`` | ``"W"`` | ``"K"``), ``smoother`` (``"jacobi"`` |
      ``"chebyshev"``), ``pre_sweeps``/``post_sweeps``, ``cheby_degree``,
      ``precondition`` (False = plain CG, the paper's baseline ablation).

    Multi-RHS:

    * ``exact_columns`` — blocked solves reproduce looped single-RHS solves
      bitwise (eager backends); False trades that for vmapped batched
      operator applications.

    Robustness (PR 8/PR 9 — see README "Robustness & failure handling"):

    * ``guard`` — per-column breakdown detection in the PCG loops
      (non-finite residual, indefinite ``p·Ap``, stagnation window).
      Observational only: clean solves are bitwise-unchanged with guards
      on or off.
    * ``guard_mode`` — how the *dist* backend detects breakdowns (PR 9):
      ``"in_scan"`` (default) carries per-column int status lanes inside
      the scanned solve, so ``SolveResult.statuses`` is live device truth
      (an indefinite ``p·Ap`` freezes the column at its last finite
      iterate, exactly like the eager path); ``"postmortem"`` keeps the
      PR 8 behavior — the unguarded scan plus a host-side
      ``scan_norms_status`` reconstruction from the fetched norms. The
      eager backends ignore it (their guards are host-side loops).
    * ``stagnation_window`` — iterations without relative residual
      improvement before a solve is declared stagnated.
    * ``fallback`` — the facade's graceful-degradation ladder: on
      breakdown, retry once against a freshly rebuilt hierarchy (evicting
      a possibly-poisoned cache entry), then diagonal-preconditioned CG,
      then (``n <= dense_fallback_max``) a dense nullspace-aware direct
      solve. Every rung is recorded in ``SolveResult.diagnostics``.
    * ``dense_fallback_max`` — largest ``n`` eligible for the dense
      last-resort solve (an O(n³) factorization).
    * ``verify`` (PR 10) — the self-verification layer. ``"off"``
      (default): no checks, hot path bitwise-unchanged. ``"cheap"``: ABFT
      checksums ride the PCG iteration — every hot-path SpMV output is
      tested against the Laplacian zero-column-sum identity
      (``|1ᵀ(Ap)| <= rtol · Σ deg|p|``, a few O(nk) reductions fused into
      the existing device fetch), and every returned
      ``SolveResult.certificate`` records an *independent* host float64
      projected-residual check ``‖proj(b − Lx)‖/‖proj b‖``. A checksum
      mismatch freezes the column with status ``"sdc_spmv"``; a failed
      certificate marks it ``"sdc_certificate"`` — both feed the
      degradation ladder like any breakdown. ``"paranoid"`` adds a second
      checksum (a precomputed Rademacher witness ``u = Lw``, catching
      corruption invisible to column sums). Checks only observe: clean
      solves are bitwise-identical across all three settings. On the dist
      backend verification implies the in-scan status-lane program (the
      checksum verdict needs a code lane to land in).
    * ``triage`` (PR 9) — admission-time conditioning triage: a cheap
      host-side sanity score (degree extremes, weight dynamic range,
      component count, a few Lanczos λ-estimates) picks the *starting*
      ladder rung and guard strictness before the first breakdown.
      Opt-in; the report lands in ``SolveResult.diagnostics`` (facade)
      and ``Ticket.triage`` (service). See ``repro.api.triage``.
    * ``checkpoint_every`` (PR 9, service only) — snapshot
      ``SolverService.flush()`` progress every N completed tickets to the
      service's ``checkpoint_dir`` (0 = off); ``SolverService.resume``
      replays only unfinished work, bit-matching an uninterrupted flush.

    Distributed backend only:

    * ``dist_nnz_threshold``, ``max_dist_levels`` — which hierarchy levels
      get the 2D-sharded SpMV (the rest stay replicated).
    """

    # stopping
    tol: float = 1e-8
    max_iters: int = 200
    # setup
    coarsest_size: int = 128
    max_levels: int = 20
    elim_max_degree: int = 4
    strength_metric: str = "algebraic_distance"
    random_ordering: bool = True
    seed: int = 0
    # solve-phase SpMV execution format ("coo" | "ell" | "auto")
    matvec_backend: str = "coo"
    # setup execution mode ("superstep" = bucketed compile-once jitted
    # super-steps — sharded over the 2D edge partition on the dist
    # backend; "eager" = host-driven reference loop), the optional
    # power-of-two floor on the super-step padding buckets, the
    # elimination Schur-sizing policy ("conservative" fuses select+build
    # into one fetch; "exact" keeps the two-fetch split), and the opt-in
    # setup-time ELL strength sweeps
    setup_mode: str = "superstep"
    setup_bucket_floor: int = 0
    elim_sizing: str = "conservative"
    setup_ell_sweeps: bool = False
    # cycle / smoother
    cycle: str = "V"
    smoother: str = "jacobi"
    pre_sweeps: int = 2
    post_sweeps: int = 2
    cheby_degree: int = 3
    precondition: bool = True
    # multi-RHS
    exact_columns: bool = True
    # robustness: breakdown guards + degradation ladder + triage/checkpoint
    guard: bool = True
    guard_mode: str = "in_scan"
    stagnation_window: int = 50
    fallback: bool = True
    dense_fallback_max: int = 4096
    triage: bool = False
    checkpoint_every: int = 0
    # self-verification: ABFT checksums + residual certificates (PR 10)
    verify: str = "off"
    # distributed
    dist_nnz_threshold: int = 10_000
    max_dist_levels: int = 3

    def __post_init__(self):
        # Fail in milliseconds, not after a multi-second hierarchy build.
        from repro.sparse.matvec import validate_backend

        validate_backend(self.matvec_backend)
        if self.setup_mode not in ("superstep", "eager"):
            raise ValueError(f"setup_mode must be 'superstep' or 'eager', "
                             f"got {self.setup_mode!r}")
        if self.elim_sizing not in ("conservative", "exact"):
            raise ValueError(f"elim_sizing must be 'conservative' or "
                             f"'exact', got {self.elim_sizing!r}")
        floor = self.setup_bucket_floor
        if floor < 0 or (floor & (floor - 1)):
            raise ValueError(f"setup_bucket_floor must be 0 or a power of "
                             f"two, got {floor!r}")
        if self.stagnation_window < 1:
            raise ValueError(f"stagnation_window must be >= 1, got "
                             f"{self.stagnation_window}")
        if self.dense_fallback_max < 0:
            raise ValueError(f"dense_fallback_max must be >= 0, got "
                             f"{self.dense_fallback_max}")
        if self.guard_mode not in ("in_scan", "postmortem"):
            raise ValueError(f"guard_mode must be 'in_scan' or "
                             f"'postmortem', got {self.guard_mode!r}")
        if self.checkpoint_every < 0:
            raise ValueError(f"checkpoint_every must be >= 0, got "
                             f"{self.checkpoint_every}")
        if self.verify not in ("off", "cheap", "paranoid"):
            raise ValueError(f"verify must be 'off', 'cheap' or 'paranoid', "
                             f"got {self.verify!r}")

    def guard_config(self):
        """The Krylov-layer guard policy this maps to (None = guards off)."""
        from repro.core.krylov import GuardConfig

        if not self.guard:
            return None
        return GuardConfig(stagnation_window=self.stagnation_window)

    def verify_config(self):
        """The checksum policy this maps to (None = verification off)."""
        from repro.core.verify import VerifyConfig

        if self.verify == "off":
            return None
        return VerifyConfig(mode=self.verify, seed=self.seed)

    def setup_config(self) -> SetupConfig:
        """The core-layer setup configuration this maps to."""
        return SetupConfig(
            max_levels=self.max_levels,
            coarsest_size=self.coarsest_size,
            elim_max_degree=self.elim_max_degree,
            strength_metric=self.strength_metric,
            aggregation=AggregationConfig(),
            seed=self.seed,
            matvec_backend=self.matvec_backend,
            setup_mode=self.setup_mode,
            setup_bucket_floor=self.setup_bucket_floor,
            elim_sizing=self.elim_sizing,
            setup_ell_sweeps=self.setup_ell_sweeps)

    def cycle_config(self) -> CycleConfig:
        """The core-layer cycle/smoother configuration this maps to."""
        return CycleConfig(
            kind=self.cycle,
            smoother=SmootherConfig(
                kind=self.smoother,
                pre_sweeps=self.pre_sweeps,
                post_sweeps=self.post_sweeps,
                cheby_degree=self.cheby_degree))
