"""The facade: ``setup`` once, ``solve`` many — any backend, one surface.

    from repro.api import Problem, SolverOptions, setup, solve

    problem = Problem.from_edges(n, rows, cols, vals)
    solver = setup(problem)                      # backend="auto"
    x, result = solver.solve(b)                  # one RHS
    X, result = solver.solve(B)                  # B: (n, k) — blocked PCG
    x, result = solve(problem, b)                # one-shot convenience

This is the paper's own shape — one algorithm "amenable to linear algebra
using arbitrary distributions" — surfaced the way LAMG ships it: a setup
phase that builds the hierarchy, then any number of solves against it.

Failure handling (PR 8): the Krylov layer's breakdown guards surface
per-column status codes, and on a breakdown the facade walks a
graceful-degradation ladder (``SolverOptions.fallback``):

1. invalidate the problem's cache entries and retry once against a
   freshly rebuilt hierarchy (a poisoned cached setup must not keep
   serving),
2. diagonal-preconditioned CG straight off the edge list (no hierarchy
   trusted at all — the paper's own baseline),
3. for ``n <= dense_fallback_max``, a dense nullspace-aware direct solve.

Every rung is recorded in ``SolveResult.diagnostics``; the overall
``SolveResult.status`` is ``"degraded"`` when a rung recovered the solve
and ``"failed"`` when the ladder is exhausted — never an unhandled NaN.

Admission triage (PR 9): with ``SolverOptions(triage=True)``, setup also
runs a cheap host-side conditioning score (``repro.api.triage``) that
picks the *starting* rung before any breakdown — a numerically hopeless
graph goes straight to the diag-PCG or dense rung instead of burning a
full multigrid solve first, and a merely suspicious one keeps multigrid
under a tightened guard. The report is the first ``diagnostics`` entry
of every solve (``stage="triage"``) and is exposed as ``Solver.triage``.
"""

from __future__ import annotations

import time

import numpy as np

from repro.api.cache import HierarchyCache
from repro.api.options import SolverOptions
from repro.api.problem import Problem
from repro.api.registry import get_backend, resolve_backend
from repro.api.result import (SolveResult, STATUS_DEGRADED, STATUS_FAILED,
                              has_breakdown, result_from_history,
                              worst_status)

# Registration side effect: importing the facade makes the built-ins
# available, so ``from repro.api import solve; solve(...)`` just works.
from repro.api import backends as _backends  # noqa: F401


class Solver:
    """One multigrid setup, any number of (possibly blocked) solves.

    Construct with :func:`setup`. Thread-compatible with the legacy
    objects: ``solver.stats()`` reports the hierarchy, ``solver.backend``
    the resolved backend name.
    """

    def __init__(self, problem: Problem, options: SolverOptions,
                 backend: str, handle, setup_seconds: float,
                 mesh=None, cache: HierarchyCache | None = None):
        self.problem = problem
        self.options = options
        self.backend = backend
        self.setup_seconds = setup_seconds
        self._handle = handle
        self._mesh = mesh
        self._cache = cache
        # Admission-time conditioning triage (PR 9, opt-in). Computed at
        # construction — i.e. at admission, cache hit or not — so the
        # routing decision exists before the first solve. The expensive
        # part of the score is memoized on the Problem.
        if options.triage:
            from repro.api.triage import triage_problem

            self.triage = triage_problem(problem, options)
        else:
            self.triage = None

    # ------------------------------------------------------------------
    def _run(self, handle, B, tol, max_iters, x0, guard=None):
        """One solve attempt through a backend handle, normalized to the
        4-tuple ``(X, norms, iters, statuses)`` — third-party handles may
        still return the legacy 3-tuple (statuses=None). ``guard``
        overrides the handle's options-derived guard policy (the triage
        layer passes a tightened GuardConfig); third-party handles that
        predate the kwarg are retried without it."""
        kwargs = {}
        if x0 is not None:
            kwargs["x0"] = x0
        if guard is not None:
            kwargs["guard"] = guard
        try:
            out = handle.solve_block(B, tol, max_iters, **kwargs)
        except TypeError:
            if "guard" not in kwargs:
                raise
            del kwargs["guard"]
            out = handle.solve_block(B, tol, max_iters, **kwargs)
        if len(out) == 3:
            X, norms, iters = out
            return X, norms, iters, None
        return out

    def solve(self, b, *, tol: float | None = None,
              max_iters: int | None = None, x0=None
              ) -> tuple[np.ndarray, SolveResult]:
        """Solve L x = b. ``b``: (n,) for one RHS or (n, k) for a block.

        ``tol``/``max_iters`` default to the solver's options. ``x0`` is
        an optional initial guess shaped like ``b`` (eager backends only;
        the default ``None`` starts from zeros, unchanged behavior).
        Returns ``(x, SolveResult)`` with ``x`` matching the shape of
        ``b``. On a Krylov breakdown the degradation ladder runs (see
        module docstring); inspect ``result.status`` / ``.diagnostics``.
        """
        tol = self.options.tol if tol is None else tol
        max_iters = self.options.max_iters if max_iters is None else max_iters
        b = np.asarray(b)
        single = b.ndim == 1
        B = b[:, None] if single else b
        if B.ndim != 2 or B.shape[0] != self.problem.n:
            raise ValueError(
                f"b must have shape ({self.problem.n},) or "
                f"({self.problem.n}, k), got {np.asarray(b).shape}")
        if x0 is not None:
            x0 = np.asarray(x0)
            if x0.shape != b.shape:
                raise ValueError(
                    f"x0 must match b's shape {b.shape}, got {x0.shape}")
            x0 = x0[:, None] if single else x0
        t0 = time.perf_counter()
        diagnostics: list = []
        status = None
        guard = None
        if self.triage is not None:
            diagnostics.append(self.triage.as_diagnostics())
            guard = self.triage.guard
        if self.triage is not None and self.triage.rung in ("diag_pcg",
                                                            "dense"):
            # triage routed AWAY from the multigrid path at admission —
            # go straight to the chosen ladder rung, no breakdown needed.
            X, norms, iters, statuses, wpi = self._triage_route(
                self.triage.rung, B, tol, max_iters, x0, diagnostics)
        else:
            X, norms, iters, statuses = self._run(self._handle, B, tol,
                                                  max_iters, x0,
                                                  guard=guard)
            wpi = self._handle.work_per_iteration
            if has_breakdown(statuses) and self.options.fallback:
                X, norms, iters, statuses, wpi, status = self._degrade(
                    B, tol, max_iters, x0, X, norms, iters, statuses,
                    diagnostics)
        if x0 is None:
            ref_norms = None
        else:
            # warm starts converge relative to ||proj b|| (the solver's
            # own reference), not the guess's possibly-tiny r0
            Bc = np.asarray(B, np.float64)
            ref_norms = np.linalg.norm(Bc - Bc.mean(axis=0, keepdims=True),
                                       axis=0)
        # PR 10: independent residual certification. The certificate is a
        # host float64 projected-residual check straight off the problem's
        # edge list — none of the device arrays the solve used are trusted.
        # A failed certificate marks the offending columns
        # "sdc_certificate" and (with fallback on) gets ONE ladder pass +
        # re-certification; a solve that still fails its certificate is
        # reported "failed", never silently returned.
        certificate = None
        if self.options.verify != "off":
            certificate = self._certify(B, X, tol, norms, ref_norms)
            if not certificate.passed:
                statuses = self._mark_cert_failure(statuses, certificate)
                if self.options.fallback and status != STATUS_FAILED:
                    X, norms, iters, statuses, wpi, status = self._degrade(
                        B, tol, max_iters, x0, X, norms, iters, statuses,
                        diagnostics)
                    certificate = self._certify(B, X, tol, norms, ref_norms)
                    if not certificate.passed:
                        statuses = self._mark_cert_failure(statuses,
                                                           certificate)
                        status = STATUS_FAILED
        solve_seconds = time.perf_counter() - t0
        result = result_from_history(
            self.backend, norms, iters, tol, wpi, self.setup_seconds,
            solve_seconds, ref_norms=ref_norms, statuses=statuses,
            diagnostics=tuple(diagnostics), status=status,
            certificate=certificate)
        return (X[:, 0] if single else X), result

    # ------------------------------------------------------------------
    def _certify(self, B, X, tol, norms, ref_norms):
        """Independent float64 certificate for the solve's claim, judged
        only on the columns that *claimed* convergence (an honest
        ``max_iters`` outcome is not silent corruption)."""
        from repro.core.verify import certify

        norms_a = np.asarray(norms, np.float64)
        if norms_a.ndim == 1:
            norms_a = norms_a[:, None]
        ref = (norms_a[0] if ref_norms is None
               else np.asarray(ref_norms, np.float64))
        with np.errstate(invalid="ignore"):
            claimed = norms_a[-1] <= tol * ref
        return certify(self.problem, B, X, tol, claimed=claimed)

    @staticmethod
    def _mark_cert_failure(statuses, certificate):
        """Per-column statuses with certificate-failing columns marked
        ``"sdc_certificate"`` (building the array from the certificate's
        claim mask when the backend reported none)."""
        from repro.core.krylov import (STATUS_CONVERGED, STATUS_MAX_ITERS,
                                       STATUS_SDC_CERT)

        if statuses is None:
            claimed = np.asarray(certificate.claimed, bool)
            sts = np.where(claimed, STATUS_CONVERGED,
                           STATUS_MAX_ITERS).astype("<U24")
        else:
            sts = np.asarray(statuses, dtype="<U24").copy()
        failed = np.asarray(certificate.failed_columns(), np.int64)
        sts[failed] = STATUS_SDC_CERT
        return sts

    # ------------------------------------------------------------------
    def _triage_route(self, rung, B, tol, max_iters, x0, diagnostics):
        """Run a triage-chosen non-multigrid rung directly. Returns
        ``(X, norms, iters, statuses, work_per_iteration)`` and appends a
        diagnostics entry per rung that ran (the ``stage="triage"`` entry
        is already in place)."""
        from repro.api.fallback import dense_solve_block, diag_pcg_block

        opts = self.options

        def record(stage, sts):
            diagnostics.append(dict(
                stage=stage, status=worst_status(sts),
                statuses=np.asarray(sts).tolist(),
                recovered=not has_breakdown(sts)))

        if rung == "diag_pcg":
            X, norms, iters, statuses = diag_pcg_block(
                self.problem, B, tol, max_iters,
                guard=opts.guard_config() or False, x0=x0)
            record("diag_pcg", statuses)
            if (has_breakdown(statuses) and opts.fallback
                    and self.problem.n <= opts.dense_fallback_max):
                X, norms, iters, statuses = dense_solve_block(
                    self.problem, B, tol)
                record("dense", statuses)
                return X, norms, iters, statuses, float(self.problem.n)
            return X, norms, iters, statuses, 1.0
        X, norms, iters, statuses = dense_solve_block(self.problem, B, tol)
        record("dense", statuses)
        return X, norms, iters, statuses, float(self.problem.n)

    # ------------------------------------------------------------------
    def _degrade(self, B, tol, max_iters, x0, X, norms, iters, statuses,
                 diagnostics):
        """Walk the degradation ladder after a breakdown. Returns the
        final ``(X, norms, iters, statuses, work_per_iteration, status)``
        and appends one diagnostics entry per rung that ran."""
        opts = self.options

        def record(stage, sts, note=None):
            diagnostics.append(dict(
                stage=stage, status=worst_status(sts),
                statuses=np.asarray(sts).tolist(),
                recovered=not has_breakdown(sts),
                **({} if note is None else dict(note=note))))

        record("primary", statuses)
        wpi = self._handle.work_per_iteration

        # rung 1: evict + rebuild the hierarchy, retry once ---------------
        note = None
        if self._cache is not None:
            n_inv = self._cache.invalidate(self.problem.fingerprint())
            note = f"invalidated {n_inv} cache entries"
        try:
            handle = get_backend(self.backend)(self.problem, opts, self._mesh)
            X, norms, iters, statuses = self._run(handle, B, tol,
                                                  max_iters, x0)
            wpi = handle.work_per_iteration
            record("rebuild", statuses, note)
            if not has_breakdown(statuses):
                # adopt (and re-cache) the healthy rebuild
                self._handle = handle
                if self._cache is not None:
                    self._cache.put(HierarchyCache.key(
                        self.problem, opts, self.backend, self._mesh),
                        handle)
                return X, norms, iters, statuses, wpi, STATUS_DEGRADED \
                    if worst_status(statuses) == "converged" else None
        except Exception as e:                      # rebuild itself died
            record("rebuild", statuses, f"{note + '; ' if note else ''}"
                                        f"rebuild raised {e!r}")

        # rung 2: diagonal-preconditioned CG off the edge list ------------
        from repro.api.fallback import diag_pcg_block

        try:
            X, norms, iters, statuses = diag_pcg_block(
                self.problem, B, tol, max_iters,
                guard=opts.guard_config() or False, x0=x0)
            wpi = 1.0
            record("diag_pcg", statuses)
            if not has_breakdown(statuses):
                return X, norms, iters, statuses, wpi, STATUS_DEGRADED \
                    if worst_status(statuses) == "converged" else None
        except Exception as e:
            record("diag_pcg", statuses, f"raised {e!r}")

        # rung 3: dense nullspace-aware direct solve (small n) ------------
        if self.problem.n <= opts.dense_fallback_max:
            from repro.api.fallback import dense_solve_block

            try:
                X, norms, iters, statuses = dense_solve_block(
                    self.problem, B, tol)
                wpi = float(self.problem.n)
                record("dense", statuses)
                if not has_breakdown(statuses):
                    return X, norms, iters, statuses, wpi, STATUS_DEGRADED \
                        if worst_status(statuses) == "converged" else None
            except Exception as e:
                record("dense", statuses, f"raised {e!r}")
        else:
            diagnostics.append(dict(
                stage="dense", status="skipped", statuses=[],
                recovered=False,
                note=f"n={self.problem.n} exceeds "
                     f"dense_fallback_max={opts.dense_fallback_max}"))

        return X, norms, iters, statuses, wpi, STATUS_FAILED

    def stats(self) -> dict:
        """Hierarchy statistics (per-level kind / size / nnz)."""
        return self._handle.stats()


# ----------------------------------------------------------------------
_DEFAULT_CACHE = HierarchyCache()


def default_cache() -> HierarchyCache:
    """The process-wide :class:`HierarchyCache` every ``setup()``/
    ``solve()`` call threads through unless told otherwise."""
    return _DEFAULT_CACHE


def setup(problem: Problem, options: SolverOptions | None = None,
          backend: str = "auto", mesh=None,
          cache: HierarchyCache | bool | None = None) -> Solver:
    """Build (or reuse) the multigrid hierarchy for ``problem``.

    ``backend`` is a registry name (``"single"``, ``"serial_ref"``,
    ``"dist"``) or ``"auto"``, which picks ``"dist"`` when a distributed
    context is available (a ``mesh`` was passed or more than one JAX device
    is visible) and ``"single"`` otherwise. ``mesh`` is only consumed by
    the dist backend; passing one forces it.

    ``cache`` — hierarchies are content-addressed: by default the lookup
    goes through :func:`default_cache`, so a second ``setup()`` on an
    equal Problem (same :meth:`Problem.fingerprint`, options, backend,
    mesh) reuses the stored backend handle and does zero setup work
    (``setup_seconds == 0.0`` on the returned Solver). Pass a
    :class:`HierarchyCache` to use a private cache, or ``False`` to
    always rebuild.
    """
    if not isinstance(problem, Problem):
        raise TypeError(
            f"setup expects a repro.api.Problem (see Problem.from_edges), "
            f"got {type(problem).__name__}")
    options = options or SolverOptions()
    name = resolve_backend(backend, mesh, options)
    if mesh is not None and name != "dist":
        raise ValueError(
            f"a mesh is only consumed by the dist backend, but "
            f"backend={name!r} was requested")
    # NB: identity checks, not truthiness — an *empty* HierarchyCache is
    # len() == 0 and must still be consulted/filled.
    if cache is None or cache is True:
        cache = _DEFAULT_CACHE
    elif cache is False:
        cache = None
    if cache is not None:
        key = HierarchyCache.key(problem, options, name, mesh)
        handle = cache.get(key)
        if handle is not None:
            return Solver(problem, options, name, handle, 0.0,
                          mesh=mesh, cache=cache)
    t0 = time.perf_counter()
    handle = get_backend(name)(problem, options, mesh)
    seconds = time.perf_counter() - t0
    if cache is not None:
        cache.put(key, handle)
    return Solver(problem, options, name, handle, seconds,
                  mesh=mesh, cache=cache)


def solve(problem: Problem, b, options: SolverOptions | None = None,
          backend: str = "auto", mesh=None,
          cache: HierarchyCache | bool | None = None
          ) -> tuple[np.ndarray, SolveResult]:
    """One-shot convenience: ``setup(...)`` then ``solve(b)``.

    Threads the hierarchy cache like :func:`setup`, so repeated one-shot
    ``solve()`` calls on an equal Problem only build the hierarchy once.
    For repeated right-hand sides prefer keeping the :class:`Solver` from
    :func:`setup` or batching them as the columns of ``b``.
    """
    return setup(problem, options, backend, mesh, cache=cache).solve(b)
