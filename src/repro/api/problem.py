"""``Problem``: a validated graph-Laplacian system, backend-agnostic.

The paper's solver acts on L = diag(deg) − A for a weighted undirected
graph. ``Problem`` is the one place that turns user-facing graph inputs
(edge lists, COO triples, adjacency matrices) into the canonical form every
backend consumes — both edge directions present, no self loops, positive
float weights — and rejects the malformed inputs that the lower layers
would otherwise absorb silently (``to_laplacian_coo`` sums duplicate edges
without complaint; a solver fed an asymmetric adjacency quietly solves the
wrong system).
"""

from __future__ import annotations

import dataclasses

import numpy as np

_DTYPES = {"float32": np.float32, "float64": np.float64}


class ProblemValidationError(ValueError):
    """A graph input failed ``Problem`` validation."""


def _as_dtype(dtype) -> np.dtype:
    if isinstance(dtype, str):
        if dtype not in _DTYPES:
            raise ProblemValidationError(
                f"dtype must be one of {sorted(_DTYPES)}, got {dtype!r}")
        return np.dtype(_DTYPES[dtype])
    dt = np.dtype(dtype)
    if dt not in (np.dtype(np.float32), np.dtype(np.float64)):
        raise ProblemValidationError(
            f"dtype must be float32 or float64, got {dt}")
    return dt


@dataclasses.dataclass(frozen=True, eq=False)
class Problem:
    """A graph-Laplacian system L x = b, ready for any backend.

    ``rows``/``cols``/``vals`` hold the adjacency edge list with BOTH
    directions present (2·|E| entries), no self loops, positive weights.
    Construct via :meth:`from_edges` or :meth:`from_adjacency` — the
    constructors validate; the raw dataclass constructor does not.

    ``dtype`` is the storage dtype policy for the weights (float32 or
    float64). Backends currently compute in float32 (the paper's precision);
    float64 inputs are accepted and cast at setup.
    """

    n: int
    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    dtype: np.dtype = np.dtype(np.float32)

    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(n: int, rows, cols, vals=None, *,
                   allow_duplicates: bool = False,
                   symmetrize: bool = False,
                   dtype="float32") -> "Problem":
        """Build a Problem from an edge list / COO triples.

        ``rows``/``cols`` are vertex indices; ``vals`` are positive edge
        weights (default: all ones). The list must contain both directions
        of every undirected edge — pass ``symmetrize=True`` to supply each
        edge once and have the reverse direction added.

        Validation (raises ``ProblemValidationError``):

        * indices in range ``[0, n)``,
        * no self loops (they contribute nothing to a Laplacian; remove
          them from the input),
        * no duplicate (u, v) entries — duplicates are almost always an
          input bug that would silently *sum* into one heavier edge; pass
          ``allow_duplicates=True`` to keep that summing behavior,
        * weights positive and finite,
        * the (possibly symmetrized) list is symmetric: (u, v) and (v, u)
          both present with equal weight.
        """
        dt = _as_dtype(dtype)
        if n < 1:
            raise ProblemValidationError(f"n must be >= 1, got {n}")
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        if rows.ndim != 1 or cols.ndim != 1 or rows.shape != cols.shape:
            raise ProblemValidationError(
                f"rows/cols must be equal-length 1-D arrays, got shapes "
                f"{rows.shape} and {cols.shape}")
        if not (np.issubdtype(rows.dtype, np.integer)
                and np.issubdtype(cols.dtype, np.integer)):
            raise ProblemValidationError(
                f"rows/cols must be integer arrays, got {rows.dtype} and "
                f"{cols.dtype}")
        if vals is None:
            vals = np.ones(len(rows), dt)
        vals = np.asarray(vals)
        if vals.shape != rows.shape:
            raise ProblemValidationError(
                f"vals must match rows/cols length, got {vals.shape} vs "
                f"{rows.shape}")
        rows = rows.astype(np.int64)
        cols = cols.astype(np.int64)
        vals = vals.astype(dt)

        oob = (rows < 0) | (rows >= n) | (cols < 0) | (cols >= n)
        if oob.any():
            i = int(np.flatnonzero(oob)[0])
            raise ProblemValidationError(
                f"edge {i} = ({rows[i]}, {cols[i]}) has a vertex index "
                f"outside [0, {n})")
        loops = rows == cols
        if loops.any():
            i = int(np.flatnonzero(loops)[0])
            raise ProblemValidationError(
                f"self-loop at vertex {rows[i]} (edge {i}): self loops do "
                f"not contribute to a graph Laplacian — remove them from "
                f"the input")
        if not np.isfinite(vals).all():
            i = int(np.flatnonzero(~np.isfinite(vals))[0])
            raise ProblemValidationError(
                f"edge {i} has non-finite weight {vals[i]}")
        if (vals <= 0).any():
            i = int(np.flatnonzero(vals <= 0)[0])
            raise ProblemValidationError(
                f"edge {i} = ({rows[i]}, {cols[i]}) has non-positive weight "
                f"{vals[i]}: the paper's solver assumes positively weighted "
                f"graphs")

        if symmetrize:
            rows, cols = (np.concatenate([rows, cols]),
                          np.concatenate([cols, rows]))
            vals = np.concatenate([vals, vals])

        key = rows * n + cols
        uniq, first_idx, counts = np.unique(key, return_index=True,
                                            return_counts=True)
        if (counts > 1).any():
            if not allow_duplicates:
                i = int(first_idx[np.flatnonzero(counts > 1)[0]])
                raise ProblemValidationError(
                    f"duplicate edge ({rows[i]}, {cols[i]}) appears "
                    f"{int(counts[np.flatnonzero(counts > 1)[0]])} times: "
                    f"duplicates would silently sum into one heavier edge; "
                    f"pass allow_duplicates=True to keep that behavior")
            # keep the summing semantics but collapse here so the symmetry
            # check below sees one entry per direction
            sums = np.zeros(len(uniq), dt)
            np.add.at(sums, np.searchsorted(uniq, key), vals)
            rows = (uniq // n).astype(np.int64)
            cols = (uniq % n).astype(np.int64)
            vals = sums

        # symmetry: the reverse of every edge must be present, equal weight
        rev_key = cols * n + rows
        order = np.argsort(rows * n + cols, kind="stable")
        rev_order = np.argsort(rev_key, kind="stable")
        if not (np.array_equal((rows * n + cols)[order], rev_key[rev_order])
                and np.allclose(vals[order], vals[rev_order], rtol=1e-6)):
            raise ProblemValidationError(
                "edge list is not symmetric: every undirected edge must "
                "appear as both (u, v) and (v, u) with equal weight — pass "
                "symmetrize=True to supply each edge once")

        return Problem(n=int(n), rows=rows.astype(np.int32),
                       cols=cols.astype(np.int32), vals=vals, dtype=dt)

    # ------------------------------------------------------------------
    @staticmethod
    def from_adjacency(a, *, dtype="float32") -> "Problem":
        """Build a Problem from a dense numpy or scipy.sparse adjacency.

        The matrix must be symmetric with non-negative entries; the diagonal
        must be zero (self loops are rejected, as in :meth:`from_edges`).
        Duplicate entries in a scipy COO are summed first — scipy's own
        semantics for them.
        """
        try:
            import scipy.sparse as sp
            is_sparse = sp.issparse(a)
        except ImportError:                           # pragma: no cover
            is_sparse = False
        if is_sparse:
            coo = a.tocoo(copy=True)
            if coo.shape[0] != coo.shape[1]:
                raise ProblemValidationError(
                    f"adjacency must be square, got {coo.shape}")
            coo.sum_duplicates()
            n, r, c, v = coo.shape[0], coo.row, coo.col, coo.data
        else:
            a = np.asarray(a)
            if a.ndim != 2 or a.shape[0] != a.shape[1]:
                raise ProblemValidationError(
                    f"adjacency must be a square matrix, got shape {a.shape}")
            r, c = np.nonzero(a)
            n, v = a.shape[0], a[r, c]
        try:
            return Problem.from_edges(n, r, c, v, dtype=dtype)
        except ProblemValidationError as e:
            if "not symmetric" in str(e):
                raise ProblemValidationError(
                    "adjacency matrix is not symmetric: A[u, v] must equal "
                    "A[v, u] for an undirected graph Laplacian") from None
            raise

    # ------------------------------------------------------------------
    def fingerprint(self) -> str:
        """Stable content digest of the system (hex sha256, memoized).

        Two Problems share a fingerprint iff they describe the same
        Laplacian under the same storage-dtype policy: the digest covers
        ``n``, the dtype name, and the edge list canonicalized by sorting
        on (row, col) — so it is insensitive to the order edges were
        supplied in, and sensitive to any weight change, including the
        rounding a float64 -> float32 drift would introduce (the dtype
        name *and* the weight bytes in storage dtype are both hashed).

        This is the content-address the :class:`~repro.api.cache.
        HierarchyCache` and the serving layer key on.
        """
        cached = self.__dict__.get("_fingerprint")
        if cached is not None:
            return cached
        import hashlib

        rows = np.ascontiguousarray(self.rows, np.int64)
        cols = np.ascontiguousarray(self.cols, np.int64)
        order = np.lexsort((cols, rows))
        h = hashlib.sha256()
        h.update(b"repro.problem/v1\0")
        h.update(int(self.n).to_bytes(8, "little"))
        h.update(np.dtype(self.dtype).name.encode() + b"\0")
        h.update(rows[order].tobytes())
        h.update(cols[order].tobytes())
        h.update(np.ascontiguousarray(
            np.asarray(self.vals, self.dtype)[order]).tobytes())
        digest = h.hexdigest()
        object.__setattr__(self, "_fingerprint", digest)
        return digest

    def components(self) -> tuple[np.ndarray, int]:
        """Connected components: ``(labels, n_components)``, memoized.

        ``labels`` is an int32 (n,) array of 0-based component ids. A
        connected graph returns ``n_components == 1``. The facade's
        fallback solvers and the pathological-input tests use this to
        build per-component nullspace projections
        (``repro.core.components``).
        """
        cached = self.__dict__.get("_components")
        if cached is None:
            from repro.core.components import connected_components

            cached = connected_components(self.n, self.rows, self.cols)
            object.__setattr__(self, "_components", cached)
        return cached

    def bucket_signature(self, floor: int = 0) -> tuple[int, int]:
        """The capacity buckets this problem's setup pads to.

        ``(pow2_bucket(n, floor), pow2_bucket(2|E|, floor))`` — the
        padding shapes that decide compiled super-step program reuse, and
        the grouping key the serving layer batches setups by. ``floor``
        is ``SolverOptions.setup_bucket_floor``.
        """
        from repro.core.graph import pow2_bucket

        return (pow2_bucket(self.n, floor),
                pow2_bucket(len(self.rows), floor))

    # ------------------------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self.n

    @property
    def n_edges(self) -> int:
        """Undirected edge count (the stored list has both directions)."""
        return len(self.rows) // 2

    def degrees(self) -> np.ndarray:
        """Weighted vertex degrees diag(L)."""
        deg = np.zeros(self.n, self.dtype)
        np.add.at(deg, self.rows, self.vals)
        return deg

    def to_laplacian_coo(self, capacity: int | None = None):
        """The padded adjacency COO the core hierarchy builders consume."""
        from repro.graphs.generators import to_laplacian_coo

        return to_laplacian_coo(self.n, self.rows, self.cols,
                                self.vals.astype(np.float32),
                                capacity=capacity)
