"""Backend registry: names -> solver implementations.

Built-in backends (registered by ``repro.api.backends``):

* ``"single"``     — single-device multigrid PCG (``LaplacianSolver``),
* ``"serial_ref"`` — the serial LAMG-style reference setup (greedy
  elimination + strength-ordered aggregation) with the same solve phase,
* ``"dist"``       — the 2D-distributed solver (``DistLaplacianSolver``),
* ``"auto"``       — resolves to ``"dist"`` when a mesh is passed or more
  than one JAX device is visible, else ``"single"``.

Third-party backends register with :func:`register_backend`; a backend is a
callable ``(problem, options, mesh) -> handle`` where the handle implements
``solve_block(B, tol, max_iters) -> (X, norms, iters_per_rhs)`` plus a
``work_per_iteration`` attribute and a ``stats()`` method (see
``repro.api.backends`` for the reference implementations).
"""

from __future__ import annotations

from typing import Callable

_REGISTRY: dict[str, Callable] = {}


def register_backend(name: str, setup_fn: Callable) -> None:
    """Register ``setup_fn(problem, options, mesh) -> handle`` under ``name``."""
    if name == "auto":
        raise ValueError('"auto" is reserved for backend resolution')
    _REGISTRY[name] = setup_fn


def available_backends() -> tuple[str, ...]:
    """Registered backend names (plus the ``"auto"`` selector)."""
    return tuple(sorted(_REGISTRY)) + ("auto",)


def get_backend(name: str) -> Callable:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; available: "
            f"{', '.join(available_backends())}") from None


def resolve_backend(name: str = "auto", mesh=None, options=None) -> str:
    """Resolve ``"auto"`` to a concrete backend name.

    The rule: distributed when a distributed context is available —
    ``mesh`` explicitly passed, or more than one JAX device visible —
    otherwise the single-device backend. Explicit names pass through
    (after checking they exist).

    ``options`` lets auto-resolution respect backend capabilities: the
    dist backend has no plain-CG ablation, so ``precondition=False``
    resolves to ``"single"`` unless a mesh explicitly forces dist (which
    then raises the dist backend's own clear error at setup).
    """
    if name != "auto":
        get_backend(name)
        return name
    if mesh is not None:
        return "dist"
    no_precond = options is not None and not options.precondition
    if no_precond:
        return "single"
    import jax

    return "dist" if len(jax.devices()) > 1 else "single"
