"""``repro.api`` — the public solver surface.

One Problem -> Solver -> Result pipeline over every backend::

    from repro.api import Problem, SolverOptions, solve, setup

    problem = Problem.from_edges(n, rows, cols, vals)
    x, result = solve(problem, b)                        # backend="auto"

    solver = setup(problem, SolverOptions(tol=1e-10), backend="single")
    X, result = solver.solve(B)                          # (n, k) multi-RHS

The legacy entry points (``repro.core.solver.LaplacianSolver``,
``repro.dist.solver.DistLaplacianSolver``,
``repro.core.serial_ref.serial_lamg_solver``) remain importable — they are
the backend implementations — but new code should go through this module;
see MIGRATION.md at the repo root for the old-name -> new-name map.
"""

from repro.api.cache import HierarchyCache
from repro.api.facade import Solver, default_cache, setup, solve
from repro.api.options import SolverOptions
from repro.api.problem import Problem, ProblemValidationError
from repro.api.registry import (available_backends, get_backend,
                                register_backend, resolve_backend)
from repro.api.result import SolveResult
from repro.api.triage import TriageReport, triage_problem
from repro.core.verify import Certificate

__all__ = [
    "Certificate",
    "HierarchyCache",
    "Problem",
    "ProblemValidationError",
    "SolveResult",
    "Solver",
    "SolverOptions",
    "TriageReport",
    "available_backends",
    "default_cache",
    "get_backend",
    "register_backend",
    "resolve_backend",
    "setup",
    "solve",
    "triage_problem",
]
