"""Last-resort solvers for the facade's degradation ladder (PR 8).

When the multigrid-preconditioned solve breaks down (and a rebuilt
hierarchy breaks down again), the facade steps down to solvers with
strictly smaller trusted surfaces:

* :func:`diag_pcg_block` — CG preconditioned by diag(L)⁻¹, built directly
  from the Problem's edge list. No hierarchy, no elimination, no
  aggregation: the only setup artifact it trusts is the degree vector.
  This is the paper's own baseline (Fig 3), so degraded service quality
  is exactly "the paper without its contribution".
* :func:`dense_solve_block` — a dense nullspace-aware direct solve in
  float64, viable for small systems (``SolverOptions.dense_fallback_max``).
  Solves ``(L + α Σ_c J_c) x = P b`` where ``P`` removes per-component
  means — the regularized system is nonsingular and its solution *is* the
  pseudo-inverse solution ``L⁺ P b`` (taking per-component means of both
  sides shows ``x`` is component-mean-free).

Both are nullspace-correct on disconnected graphs via
``Problem.components()``. Return convention matches the backend handle
protocol's 4-tuple: ``(X, norms, iters, statuses)``.
"""

from __future__ import annotations

import numpy as np

from repro.core.krylov import (STATUS_CONVERGED, STATUS_MAX_ITERS,
                               STATUS_NONFINITE, GuardConfig, pcg_block)


def _projector(problem):
    comp, n_comp = problem.components()
    if n_comp == 1:
        return None
    from repro.core.components import component_projector

    return component_projector(comp, n_comp)


def diag_pcg_block(problem, B, tol, max_iters,
                   guard: GuardConfig | bool = True, x0=None):
    """Diagonal-preconditioned CG straight off the Problem's edge list."""
    import jax
    import jax.numpy as jnp

    n = problem.n
    rows = jnp.asarray(problem.rows, jnp.int32)
    cols = jnp.asarray(problem.cols, jnp.int32)
    vals = jnp.asarray(problem.vals, jnp.float32)
    deg = jnp.asarray(problem.degrees().astype(np.float32))
    inv_deg = 1.0 / jnp.maximum(deg, 1e-30)

    def matvec(v):
        return deg * v - jax.ops.segment_sum(vals * jnp.take(v, cols),
                                             rows, num_segments=n)

    X, info = pcg_block(matvec, jnp.asarray(B, jnp.float32),
                        precond=lambda r: inv_deg * r, tol=tol,
                        maxiter=max_iters, exact_columns=False,
                        x0=None if x0 is None
                        else jnp.asarray(x0, jnp.float32),
                        project=_projector(problem), guard=guard)
    return (np.asarray(X), np.asarray(info.residual_norms),
            np.asarray(info.iters, np.int64), info.status)


def dense_solve_block(problem, B, tol):
    """Dense float64 nullspace-aware direct solve (small n only)."""
    n = problem.n
    L = np.zeros((n, n), np.float64)
    r, c = problem.rows, problem.cols
    v = np.asarray(problem.vals, np.float64)
    np.add.at(L, (r, r), v)           # degrees (both directions stored)
    np.subtract.at(L, (r, c), v)
    comp, n_comp = problem.components()
    counts = np.bincount(comp, minlength=n_comp).astype(np.float64)
    alpha = float(L.trace() / n) or 1.0
    reg = (comp[:, None] == comp[None, :]) / counts[comp][:, None]

    B = np.asarray(B, np.float64)
    single = B.ndim == 1
    if single:
        B = B[:, None]
    means = np.zeros((n_comp, B.shape[1]))
    np.add.at(means, comp, B)
    Bp = B - (means / counts[:, None])[comp]
    X = np.linalg.solve(L + alpha * reg, Bp)

    r0n = np.linalg.norm(Bp, axis=0)
    rn = np.linalg.norm(Bp - L @ X, axis=0)
    norms = np.stack([r0n, rn])
    with np.errstate(invalid="ignore"):
        ok = rn <= np.asarray(tol) * r0n
    statuses = np.where(ok, STATUS_CONVERGED, STATUS_MAX_ITERS
                        ).astype("<U24")
    # a non-finite RHS (e.g. an injected NaN that survived to the last
    # rung) is a breakdown, not "clean math that ran out of iterations" —
    # report it so the ladder ends in "failed" rather than "max_iters"
    statuses[~(np.isfinite(r0n) & np.isfinite(rn))] = STATUS_NONFINITE
    return (X[:, 0] if single else X, norms,
            np.ones(B.shape[1], np.int64), statuses)
