"""``SolveResult``: identical result fields for every backend.

The three legacy entry points returned three different info objects
(``LaplacianSolveInfo``, a bare ``(x, norms)`` tuple, ``SolveInfo``). The
facade normalises them: whatever backend ran, the caller gets the same
fields with the same meanings, for one right-hand side or a block of them.

PR 8 adds the robustness surface: ``status`` (the overall outcome code),
``statuses`` (per-column Krylov status codes when the backend reports
them), and ``diagnostics`` (the recorded rungs of the facade's
degradation ladder). A clean converged solve reports
``status="converged"`` and empty diagnostics — byte-for-byte the old
behavior plus three new fields.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.krylov import (BREAKDOWN_STATUSES, STATUS_CONVERGED,
                               STATUS_MAX_ITERS)
from repro.core.verify import Certificate
from repro.core.wda import wda as _wda

# Overall-outcome codes beyond the Krylov layer's own:
STATUS_DEGRADED = "degraded"   # a ladder rung recovered the solve
STATUS_FAILED = "failed"       # breakdown and every rung exhausted


def worst_status(statuses) -> str:
    """Collapse per-column status codes to the block's overall code.

    Severity order: sdc (detected silent corruption is the worst possible
    news) > non-finite > indefinite > stagnation > max_iters > converged —
    a block is only "converged" when every column is.
    """
    order = ("sdc_spmv", "sdc_certificate",
             "breakdown_nonfinite", "breakdown_indefinite", "stagnation",
             STATUS_MAX_ITERS, STATUS_CONVERGED)
    seen = set(str(s) for s in np.asarray(statuses).ravel())
    for code in order:
        if code in seen:
            return code
    return STATUS_CONVERGED


def has_breakdown(statuses) -> bool:
    return bool(statuses is not None
                and worst_status(statuses) in BREAKDOWN_STATUSES)


@dataclasses.dataclass(frozen=True, eq=False)
class SolveResult:
    """Outcome of one ``solve`` call, backend-independent.

    * ``backend`` — registry name that ran (``"auto"`` is resolved first),
    * ``converged`` — every right-hand side reached ``tol``,
    * ``iters`` — PCG iterations of the slowest column,
    * ``iters_per_rhs`` — per-column iteration counts, shape (k,),
    * ``residual_norms`` — lockstep residual history, shape (iters+1, k)
      (converged columns hold their frozen final norm),
    * ``wda`` — Work per Digit of Accuracy (paper Fig 3 metric) over the
      block residual (Frobenius norm history),
    * ``work_per_iteration`` — one PCG iteration's cost in finest-level
      matvec equivalents,
    * ``setup_seconds`` / ``solve_seconds`` — wall-clock (setup is the
      hierarchy build of the owning ``Solver``, amortised over its solves),
    * ``n_rhs`` — number of right-hand sides (k),
    * ``status`` — overall outcome code: ``"converged"``, ``"max_iters"``
      (honest non-convergence), a breakdown code
      (``"breakdown_nonfinite"`` / ``"breakdown_indefinite"`` /
      ``"stagnation"``), ``"degraded"`` (a breakdown recovered by the
      facade's fallback ladder) or ``"failed"`` (ladder exhausted),
    * ``statuses`` — per-column Krylov status codes, shape (k,), or None
      when the backend doesn't report them (third-party handles),
    * ``diagnostics`` — tuple of dicts, one per degradation-ladder rung
      that ran (empty for a clean solve); each records the ``stage``, its
      per-column ``statuses`` and whether it ``recovered``,
    * ``certificate`` — with ``SolverOptions(verify=...)`` on, the
      independent float64 projected-residual certificate
      (``repro.core.verify.Certificate``); ``None`` with ``verify="off"``.
    """

    backend: str
    converged: bool
    iters: int
    iters_per_rhs: np.ndarray
    residual_norms: np.ndarray
    wda: float
    work_per_iteration: float
    setup_seconds: float
    solve_seconds: float
    n_rhs: int
    status: str = STATUS_CONVERGED
    statuses: np.ndarray | None = None
    diagnostics: tuple = ()
    certificate: Certificate | None = None


def result_from_history(backend: str, norms: np.ndarray,
                        iters_per_rhs: np.ndarray, tol: float,
                        work_per_iteration: float, setup_seconds: float,
                        solve_seconds: float,
                        ref_norms: np.ndarray | None = None,
                        statuses=None, diagnostics: tuple = (),
                        status: str | None = None,
                        certificate: Certificate | None = None
                        ) -> SolveResult:
    """Assemble a ``SolveResult`` from a (T+1, k) residual history.

    Trims the history at the slowest column's convergence point (frozen
    tails would otherwise inflate the WDA iteration count) and derives
    convergence from the tolerance: a column converged iff its final norm
    is within ``tol`` of its initial norm — or of ``ref_norms`` when
    given (warm-started solves measure against ``||proj b||``, not the
    initial guess's own residual).

    ``status`` defaults to the worst per-column code in ``statuses``, or
    to converged/max_iters derived from the residuals when the backend
    reported no codes. The facade overrides it with ``"degraded"`` /
    ``"failed"`` after running its ladder.
    """
    norms = np.asarray(norms, np.float64)
    if norms.ndim == 1:
        norms = norms[:, None]
    iters_per_rhs = np.asarray(iters_per_rhs, np.int64)
    it_max = int(iters_per_rhs.max()) if iters_per_rhs.size else 0
    norms = norms[: it_max + 1]
    ref = (norms[0] if ref_norms is None
           else np.asarray(ref_norms, np.float64))
    with np.errstate(invalid="ignore"):
        converged = bool(np.all(norms[-1] <= tol * ref))
    if statuses is not None:
        statuses = np.asarray(statuses)
    if status is None:
        if statuses is not None:
            status = worst_status(statuses)
        else:
            status = STATUS_CONVERGED if converged else STATUS_MAX_ITERS
    frob = np.sqrt((norms ** 2).sum(axis=1))
    return SolveResult(
        backend=backend, converged=converged, iters=it_max,
        iters_per_rhs=iters_per_rhs, residual_norms=norms,
        wda=_wda(frob.tolist(), work_per_iteration),
        work_per_iteration=float(work_per_iteration),
        setup_seconds=float(setup_seconds),
        solve_seconds=float(solve_seconds), n_rhs=norms.shape[1],
        status=status, statuses=statuses, diagnostics=tuple(diagnostics),
        certificate=certificate)
