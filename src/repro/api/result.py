"""``SolveResult``: identical result fields for every backend.

The three legacy entry points returned three different info objects
(``LaplacianSolveInfo``, a bare ``(x, norms)`` tuple, ``SolveInfo``). The
facade normalises them: whatever backend ran, the caller gets the same
fields with the same meanings, for one right-hand side or a block of them.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.wda import wda as _wda


@dataclasses.dataclass(frozen=True, eq=False)
class SolveResult:
    """Outcome of one ``solve`` call, backend-independent.

    * ``backend`` — registry name that ran (``"auto"`` is resolved first),
    * ``converged`` — every right-hand side reached ``tol``,
    * ``iters`` — PCG iterations of the slowest column,
    * ``iters_per_rhs`` — per-column iteration counts, shape (k,),
    * ``residual_norms`` — lockstep residual history, shape (iters+1, k)
      (converged columns hold their frozen final norm),
    * ``wda`` — Work per Digit of Accuracy (paper Fig 3 metric) over the
      block residual (Frobenius norm history),
    * ``work_per_iteration`` — one PCG iteration's cost in finest-level
      matvec equivalents,
    * ``setup_seconds`` / ``solve_seconds`` — wall-clock (setup is the
      hierarchy build of the owning ``Solver``, amortised over its solves),
    * ``n_rhs`` — number of right-hand sides (k).
    """

    backend: str
    converged: bool
    iters: int
    iters_per_rhs: np.ndarray
    residual_norms: np.ndarray
    wda: float
    work_per_iteration: float
    setup_seconds: float
    solve_seconds: float
    n_rhs: int


def result_from_history(backend: str, norms: np.ndarray,
                        iters_per_rhs: np.ndarray, tol: float,
                        work_per_iteration: float, setup_seconds: float,
                        solve_seconds: float,
                        ref_norms: np.ndarray | None = None) -> SolveResult:
    """Assemble a ``SolveResult`` from a (T+1, k) residual history.

    Trims the history at the slowest column's convergence point (frozen
    tails would otherwise inflate the WDA iteration count) and derives
    convergence from the tolerance: a column converged iff its final norm
    is within ``tol`` of its initial norm — or of ``ref_norms`` when
    given (warm-started solves measure against ``||proj b||``, not the
    initial guess's own residual).
    """
    norms = np.asarray(norms, np.float64)
    if norms.ndim == 1:
        norms = norms[:, None]
    iters_per_rhs = np.asarray(iters_per_rhs, np.int64)
    it_max = int(iters_per_rhs.max()) if iters_per_rhs.size else 0
    norms = norms[: it_max + 1]
    ref = (norms[0] if ref_norms is None
           else np.asarray(ref_norms, np.float64))
    converged = bool(np.all(norms[-1] <= tol * ref))
    frob = np.sqrt((norms ** 2).sum(axis=1))
    return SolveResult(
        backend=backend, converged=converged, iters=it_max,
        iters_per_rhs=iters_per_rhs, residual_norms=norms,
        wda=_wda(frob.tolist(), work_per_iteration),
        work_per_iteration=float(work_per_iteration),
        setup_seconds=float(setup_seconds),
        solve_seconds=float(solve_seconds), n_rhs=norms.shape[1])
