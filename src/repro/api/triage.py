"""Admission-time conditioning triage (PR 9).

The degradation ladder (PR 8) is reactive: the multigrid path must
*break* before the facade reaches for a cheaper rung. At scale that is
wasted work — a request whose graph is numerically hopeless for the
float32 multigrid path (weight dynamic range beyond what float32 can
even represent across a V-cycle, condition estimates past the attainable
accuracy) burns a full setup + breakdown + rebuild before landing where
triage could have sent it immediately. LAMG (arXiv:1108.0123) picks
methods from conditioning measures at setup time; Sachdeva–Zhao
(arXiv:2304.14345) motivates structurally different cheap fallbacks.
This module is the admission-side version of both ideas: a **cheap,
host-side sanity score** computed once per problem —

* degree extremes (max/min positive weighted degree),
* weight dynamic range (max/min nonzero |w|),
* connected component count,
* a few float64 Lanczos iterations for λ-extreme estimates
  (:func:`lanczos_extremes` — O(k·m), k≈8, deterministic),

— mapped to a **starting ladder rung** and a **guard strictness** before
the first breakdown:

==================  ========================================================
``multigrid``       healthy: the normal path with the options' guards
``multigrid_strict`` suspicious conditioning: multigrid, but with a halved
                    stagnation window so a doomed solve is cut short early
``diag_pcg``        conditioning beyond multigrid's float32 reach and the
                    graph too large for dense: diagonal-PCG rung directly
``dense``           conditioning beyond iterative reach and
                    ``n <= dense_fallback_max``: float64 direct solve
==================  ========================================================

Opt-in via ``SolverOptions(triage=True)``. The report is recorded in
``SolveResult.diagnostics`` (facade) and on ``Ticket.triage`` (service).
The expensive part of the score (the Lanczos sweep) is memoized on the
``Problem``, so admission triage of the same problem under different
options re-derives only the rung decision.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.krylov import GuardConfig

RUNG_MULTIGRID = "multigrid"
RUNG_MULTIGRID_STRICT = "multigrid_strict"
RUNG_DIAG_PCG = "diag_pcg"
RUNG_DENSE = "dense"

RUNGS = (RUNG_MULTIGRID, RUNG_MULTIGRID_STRICT, RUNG_DIAG_PCG, RUNG_DENSE)

# Conditioning thresholds. Deliberately generous: the robustness suite
# (PR 8) shows the float32 multigrid path absorbs 1e12 weight ranges, so
# triage only routes away when the score is far beyond that — a false
# "route away" on a workable graph costs more than it saves.
_STRICT_RANGE = 1e8       # weight range / cond-hat that tightens guards
_HOPELESS_RANGE = 1e14    # weight range beyond float32's iterative reach
_HOPELESS_COND = 1e12     # λmax/λsmall estimate beyond attainable accuracy


@dataclasses.dataclass(frozen=True)
class TriageReport:
    """Admission decision for one problem under one options set.

    ``rung`` — the starting ladder rung; ``guard`` — a tightened
    :class:`GuardConfig` when triage asks for stricter-than-options
    guards, None to keep the options default; ``score`` — the raw
    indicator dict the decision was derived from (JSON-friendly floats).
    """

    rung: str
    guard: GuardConfig | None
    score: dict

    def as_diagnostics(self) -> dict:
        """The ``SolveResult.diagnostics`` entry shape for this report."""
        return dict(stage="triage", status=self.rung, statuses=[],
                    recovered=True, rung=self.rung, score=dict(self.score),
                    strict_guard=self.guard is not None)


def lanczos_extremes(problem, k: int = 8, seed: int = 0
                     ) -> tuple[float, float]:
    """(λmax, λsmall) Ritz estimates of the Laplacian, float64 host math.

    ``k`` Lanczos iterations with full reorthogonalisation against the
    kept basis, started from a seeded mean-free random vector — O(k·m)
    and deterministic. λmax comes out sharp within a few percent; λsmall
    (the smallest positive Ritz value) is a crude upper bound on λ₂, good
    enough for an order-of-magnitude condition estimate — triage
    thresholds are decades apart, not percent apart.
    """
    n = problem.n
    rows = np.asarray(problem.rows)
    cols = np.asarray(problem.cols)
    vals = np.asarray(problem.vals, np.float64)
    deg = np.zeros(n, np.float64)
    np.add.at(deg, rows, vals)

    def mv(x):
        y = deg * x
        np.add.at(y, rows, -vals * x[cols])
        return y

    rng = np.random.default_rng(seed)
    q = rng.normal(size=n)
    q -= q.mean()
    nq = np.linalg.norm(q)
    if nq == 0 or not np.isfinite(nq):         # pragma: no cover
        return 0.0, 0.0
    q /= nq
    Q = [q]
    alphas, betas = [], []
    for _ in range(min(k, n - 1) if n > 1 else 1):
        w = mv(Q[-1])
        a = float(Q[-1] @ w)
        alphas.append(a)
        w = w - a * Q[-1]
        if len(Q) > 1:
            w = w - betas[-1] * Q[-2]
        for qi in Q:                            # full reorthogonalisation
            w = w - (qi @ w) * qi
        w = w - w.mean()
        b = float(np.linalg.norm(w))
        if not np.isfinite(b) or b < 1e-300:
            break
        betas.append(b)
        Q.append(w / b)
    if not alphas or not np.all(np.isfinite(alphas)):
        return float("inf"), 0.0
    m = len(alphas)
    T = np.diag(alphas)
    for i, b in enumerate(betas[: m - 1]):
        T[i, i + 1] = T[i + 1, i] = b
    ritz = np.linalg.eigvalsh(T)
    lam_max = float(ritz.max(initial=0.0))
    pos = ritz[ritz > 1e-12 * max(lam_max, 1.0)]
    lam_small = float(pos.min()) if pos.size else 0.0
    return lam_max, lam_small


def triage_score(problem, lanczos_k: int = 8) -> dict:
    """The raw indicator dict (options-independent, memoized on the
    Problem): degree extremes, weight dynamic range, component count and
    the Lanczos λ-estimates."""
    cached = problem.__dict__.get("_triage_score")
    if cached is not None:
        return cached
    w = np.abs(np.asarray(problem.vals, np.float64))
    wnz = w[w > 0]
    weight_range = float(wnz.max() / wnz.min()) if wnz.size else 1.0
    deg = np.asarray(problem.degrees(), np.float64)
    dpos = deg[deg > 0]
    degree_ratio = float(dpos.max() / dpos.min()) if dpos.size else 1.0
    _, n_components = problem.components()
    lam_max, lam_small = lanczos_extremes(problem, k=lanczos_k)
    cond_hat = (float(lam_max / lam_small) if lam_small > 0
                else float("inf") if lam_max > 0 else 1.0)
    score = dict(
        n=int(problem.n), nnz=int(len(problem.rows)),
        weight_range=weight_range, degree_ratio=degree_ratio,
        n_components=int(n_components), isolated_vertices=int((deg == 0).sum()),
        lam_max=lam_max, lam_small=lam_small, cond_hat=cond_hat,
        lanczos_k=int(lanczos_k))
    problem.__dict__["_triage_score"] = score
    return score


def triage_problem(problem, options) -> TriageReport:
    """Score ``problem`` and pick the starting rung + guard strictness.

    The decision is deliberately conservative toward the multigrid path:
    only a score decades beyond its demonstrated float32 envelope routes
    away (see module docstring), and a merely *suspicious* score keeps
    multigrid but halves the stagnation window so a doomed solve is cut
    short before burning the full iteration budget.
    """
    score = triage_score(problem)
    hopeless = (score["weight_range"] > _HOPELESS_RANGE
                or score["cond_hat"] > _HOPELESS_COND)
    suspicious = (score["weight_range"] > _STRICT_RANGE
                  or score["degree_ratio"] > _STRICT_RANGE
                  or score["cond_hat"] > _STRICT_RANGE)
    if hopeless:
        rung = (RUNG_DENSE if problem.n <= options.dense_fallback_max
                else RUNG_DIAG_PCG)
        return TriageReport(rung=rung, guard=None, score=score)
    if suspicious:
        strict = GuardConfig(
            stagnation_window=max(10, options.stagnation_window // 2))
        return TriageReport(rung=RUNG_MULTIGRID_STRICT, guard=strict,
                            score=score)
    return TriageReport(rung=RUNG_MULTIGRID, guard=None, score=score)
